//! Discrete-event simulator for the multi-client scalability study
//! (Fig 7).  Models N closed-loop clients sharing one uplink and an
//! edge server with `compute_units` parallel accelerators.
//!
//! Per request (one "conversation turn" of `output_tokens` decode
//! steps under the paper's recompute regime):
//!   client think → [per step: compress + uplink transfer of the
//!   (growing) activation + server queueing + compute] → response.
//! The uplink is a shared FIFO resource, the server a `k`-server
//! queue — exactly the two bottlenecks Fig 7 contrasts.
//!
//! The per-step byte model ([`bytes_per_step`]) is not taken on
//! faith: [`live`] drives the real serving core over an in-proc
//! transport and measures the same quantities on the actual wire.

pub mod des;
pub mod live;

use crate::codec::stream::UPDATE_WIRE_BYTES;
use crate::config::SimConfig;
use crate::coordinator::protocol::{PREFILL_HEADER_BYTES, STREAM_HEADER_BYTES};
use crate::util::json::Json;
use crate::util::rng::Rng;
use des::{EventQueue, Resource};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arm {
    /// uncompressed activations
    Original,
    /// FourierCompress at `fc_ratio`
    Fc,
    /// FourierCompress + spectral delta streaming (`codec::stream`):
    /// keyframes every `stream_keyframe_interval` steps, sparse
    /// coefficient deltas otherwise — the regime that removes the
    /// recompute retransmission
    FcStream,
    /// The full adaptive stack (`codec::rate` over the delta stream):
    /// during the slow phases of a built-in fluctuating-link trace
    /// (alternating `adaptive_phase_steps`-step phases) the
    /// controller rides a reduced ladder point keeping
    /// `adaptive_low_fill` of the block; fast phases restore full
    /// quality
    FcAdaptive,
}

/// The built-in fluctuating-link trace `Arm::FcAdaptive` models: the
/// fraction of the primary block the controller keeps at `step`
/// (fast phases 1.0, slow phases `adaptive_low_fill`).  Public so the
/// benches can audit the byte model against the real controller.
pub fn adaptive_fill(cfg: &SimConfig, step: usize) -> f64 {
    if (step / cfg.adaptive_phase_steps.max(1)) % 2 == 1 {
        cfg.adaptive_low_fill
    } else {
        1.0
    }
}

/// Per-step uplink payload bytes for one decode step under `arm` —
/// public so the benches and tests can audit the Fig-7 byte model
/// against the real codec.
///
/// Recompute regimes (`Original`, `Fc`) retransmit the full
/// (prompt + step)-token activation.  `FcStream` sends the same full
/// block only on keyframes; a delta step carries
/// `stream_delta_fill` of the block's coefficients at
/// [`UPDATE_WIRE_BYTES`] each (u32 index + f32 value, i.e. 2x a
/// keyframe float) plus the [`STREAM_HEADER_BYTES`] Delta frame
/// header — the same constants the real wire format uses.
pub fn bytes_per_step(cfg: &SimConfig, arm: Arm, step: usize) -> f64 {
    let toks = cfg.prompt_tokens + step;
    let raw = (toks * cfg.hidden * 4) as f64;
    match arm {
        Arm::Original => raw,
        Arm::Fc => raw / cfg.fc_ratio,
        Arm::FcStream | Arm::FcAdaptive => {
            // FcAdaptive scales the kept block by the trace-driven
            // ladder fill; FcStream is the fill == 1.0 special case
            let fill = match arm {
                Arm::FcAdaptive => adaptive_fill(cfg, step),
                _ => 1.0,
            };
            let key = raw / cfg.fc_ratio * fill;
            if step % cfg.stream_keyframe_interval.max(1) == 0 {
                key
            } else {
                key * cfg.stream_delta_fill * (UPDATE_WIRE_BYTES as f64 / 4.0)
                    + STREAM_HEADER_BYTES as f64
            }
        }
    }
}

/// Prompt-phase (prefill) uplink bytes under `arm` — the one-shot
/// upload that precedes decode, public so the prefill bench and tests
/// can audit the chunked-prefill byte model against the real wire.
///
/// Recompute regimes send the whole prompt plane monolithically
/// (`Original` raw, `Fc` the packed plane).  The streaming arms model
/// chunked prefill: the plane splits into `prefill_chunks` fixed-row
/// chunks — one keyframe chunk carrying its rows' coefficients dense,
/// the rest row-delta chunks retransmitting only `prefill_delta_fill`
/// of their coefficients at [`UPDATE_WIRE_BYTES`] each — every chunk
/// paying the [`PREFILL_HEADER_BYTES`] PrefillChunk frame header.
pub fn prompt_bytes(cfg: &SimConfig, arm: Arm) -> f64 {
    let raw = (cfg.prompt_tokens * cfg.hidden * 4) as f64;
    match arm {
        Arm::Original => raw,
        Arm::Fc => raw / cfg.fc_ratio,
        Arm::FcStream | Arm::FcAdaptive => {
            let plane = raw / cfg.fc_ratio;
            let n = cfg.prefill_chunks.max(1) as f64;
            let key = plane / n;
            let delta = plane / n * cfg.prefill_delta_fill
                * (UPDATE_WIRE_BYTES as f64 / 4.0);
            key + (n - 1.0) * delta + n * PREFILL_HEADER_BYTES as f64
        }
    }
}

#[derive(Debug, Clone)]
pub struct RunStats {
    pub clients: usize,
    pub link_gbps: f64,
    pub completed: usize,
    pub mean_response_s: f64,
    pub p95_response_s: f64,
    pub server_util: f64,
    pub link_util: f64,
}

/// Simulate one (clients, link, arm) cell of Fig 7.
pub fn simulate(cfg: &SimConfig, clients: usize, link_gbps: f64, arm: Arm)
    -> RunStats {
    let mut rng = Rng::new(cfg.seed ^ (clients as u64) << 8
                           ^ (link_gbps as u64) << 24
                           ^ match arm {
                               Arm::Original => 0,
                               Arm::Fc => 1,
                               Arm::FcStream => 2,
                               Arm::FcAdaptive => 3,
                           });
    let mut q = EventQueue::new();
    let mut link = Resource::new(1);
    let mut server = Resource::new(cfg.compute_units);

    // per-step activation bytes — see `bytes_per_step` for the three
    // regimes (full recompute, FC recompute, FC delta stream)
    let bytes_at = |step: usize| -> f64 { bytes_per_step(cfg, arm, step) };
    // compression cost on the device (hardware-accelerated FC is
    // sub-ms; it shows up in Fig 6, not here, but we keep it honest)
    let compress_s = match arm {
        Arm::Original => 0.0,
        Arm::Fc | Arm::FcStream | Arm::FcAdaptive => 1.0e-4,
    };
    let link_rate = link_gbps * 1e9 / 8.0; // bytes/s

    // state per in-flight request
    #[derive(Clone)]
    struct Req {
        t_start: f64,
        step: usize,
    }
    let mut reqs: Vec<Option<Req>> = vec![None; clients];
    let mut responses: Vec<f64> = Vec::new();
    let mut link_busy = 0.0f64;
    let mut server_busy = 0.0f64;

    // event kinds
    const THINK_DONE: u32 = 0;
    const LINK_GRANT: u32 = 1;
    const LINK_DONE: u32 = 2;
    const SERVER_GRANT: u32 = 3;
    const SERVER_DONE: u32 = 4;

    for c in 0..clients {
        q.schedule(rng.exp(1.0 / cfg.think_time_s), THINK_DONE, c as u64);
    }

    let service_s = cfg.service_per_token_s;
    while let Some(ev) = q.pop() {
        if ev.time > cfg.horizon_s {
            break;
        }
        let c = ev.payload as usize;
        match ev.kind {
            THINK_DONE => {
                reqs[c] = Some(Req { t_start: ev.time, step: 0 });
                link.request(&mut q, ev.time, LINK_GRANT, c as u64);
            }
            LINK_GRANT => {
                let step = reqs[c].as_ref().map(|r| r.step).unwrap_or(0);
                let dt = compress_s + bytes_at(step) / link_rate;
                link_busy += dt;
                q.schedule(ev.time + dt, LINK_DONE, c as u64);
            }
            LINK_DONE => {
                link.release(&mut q, ev.time);
                server.request(&mut q, ev.time, SERVER_GRANT, c as u64);
            }
            SERVER_GRANT => {
                // one decode step: prefix recompute + next-token
                let step = reqs[c].as_ref().map(|r| r.step).unwrap_or(0);
                let toks = cfg.prompt_tokens + step;
                let dt = service_s * (1.0 + toks as f64 / cfg.prompt_tokens as f64);
                server_busy += dt;
                q.schedule(ev.time + dt, SERVER_DONE, c as u64);
            }
            SERVER_DONE => {
                server.release(&mut q, ev.time);
                let done = {
                    let r = reqs[c].as_mut().unwrap();
                    r.step += 1;
                    r.step >= cfg.output_tokens
                };
                if done {
                    let r = reqs[c].take().unwrap();
                    responses.push(ev.time - r.t_start);
                    q.schedule(ev.time + rng.exp(1.0 / cfg.think_time_s),
                               THINK_DONE, c as u64);
                } else {
                    link.request(&mut q, ev.time, LINK_GRANT, c as u64);
                }
            }
            _ => unreachable!(),
        }
    }

    responses.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = responses.len();
    let mean = if n > 0 { responses.iter().sum::<f64>() / n as f64 } else { f64::NAN };
    let p95 = if n > 0 { responses[(n as f64 * 0.95) as usize % n] } else { f64::NAN };
    RunStats {
        clients,
        link_gbps,
        completed: n,
        mean_response_s: mean,
        p95_response_s: p95,
        server_util: server_busy / (cfg.horizon_s * cfg.compute_units as f64),
        link_util: link_busy / cfg.horizon_s,
    }
}

/// The full Fig-7 sweep: clients × link rates × {Original, FC}.
pub fn fig7(cfg: &SimConfig) -> Json {
    let mut out = Json::obj();
    out.set("compute_units", Json::Num(cfg.compute_units as f64));
    out.set("fc_ratio", Json::Num(cfg.fc_ratio));
    out.set("clients",
            Json::Arr(cfg.clients.iter().map(|&c| Json::Num(c as f64)).collect()));
    for (arm, tag) in [(Arm::Original, "orig"), (Arm::Fc, "fc"),
                       (Arm::FcStream, "fcs"), (Arm::FcAdaptive, "fca")] {
        out.set(&format!("{tag}_prompt_bytes"),
                Json::Num(prompt_bytes(cfg, arm).round()));
    }
    for &g in &cfg.link_gbps {
        for (arm, tag) in [(Arm::Original, "orig"), (Arm::Fc, "fc"),
                           (Arm::FcStream, "fcs"),
                           (Arm::FcAdaptive, "fca")] {
            let mut means = Vec::new();
            let mut utils = Vec::new();
            for &c in &cfg.clients {
                let st = simulate(cfg, c, g, arm);
                means.push(Json::Num((st.mean_response_s * 1000.0).round() / 1000.0));
                utils.push(Json::Num((st.server_util * 1000.0).round() / 1000.0));
            }
            out.set(&format!("{tag}_{g}gbps_mean_s"), Json::Arr(means));
            out.set(&format!("{tag}_{g}gbps_server_util"), Json::Arr(utils));
        }
        crate::info!("fig7", "link {g} Gbps done");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SimConfig {
        SimConfig {
            clients: vec![4],
            link_gbps: vec![1.0],
            compute_units: 1,
            think_time_s: 0.5,
            output_tokens: 8,
            prompt_tokens: 32,
            hidden: 2048,
            fc_ratio: 10.0,
            stream_keyframe_interval: 32,
            stream_delta_fill: 0.05,
            prefill_chunks: 16,
            prefill_delta_fill: 0.05,
            adaptive_phase_steps: 16,
            adaptive_low_fill: 0.35,
            service_per_token_s: 0.002,
            horizon_s: 60.0,
            seed: 1,
        }
    }

    #[test]
    fn completes_requests() {
        let st = simulate(&quick_cfg(), 4, 1.0, Arm::Fc);
        assert!(st.completed > 10, "completed {}", st.completed);
        assert!(st.mean_response_s > 0.0);
    }

    #[test]
    fn fc_beats_original_when_bandwidth_bound() {
        let mut cfg = quick_cfg();
        cfg.compute_units = 8; // ample compute: link is the bottleneck
        cfg.link_gbps = vec![0.2];
        let orig = simulate(&cfg, 32, 0.2, Arm::Original);
        let fc = simulate(&cfg, 32, 0.2, Arm::Fc);
        assert!(fc.mean_response_s < orig.mean_response_s * 0.5,
                "fc {} orig {}", fc.mean_response_s, orig.mean_response_s);
    }

    #[test]
    fn link_speed_irrelevant_when_compute_bound() {
        // Fig 7(a): single unit saturated by many clients
        let mut cfg = quick_cfg();
        cfg.compute_units = 1;
        let slow = simulate(&cfg, 64, 1.0, Arm::Fc);
        let fast = simulate(&cfg, 64, 10.0, Arm::Fc);
        let rel = (slow.mean_response_s - fast.mean_response_s).abs()
            / slow.mean_response_s;
        assert!(rel < 0.25, "rel diff {rel}");
        assert!(slow.server_util > 0.9, "util {}", slow.server_util);
    }

    #[test]
    fn more_clients_more_latency_at_saturation() {
        let cfg = quick_cfg();
        let a = simulate(&cfg, 16, 1.0, Arm::Original);
        let b = simulate(&cfg, 128, 1.0, Arm::Original);
        assert!(b.mean_response_s > a.mean_response_s);
    }

    #[test]
    fn deterministic() {
        let cfg = quick_cfg();
        let a = simulate(&cfg, 8, 1.0, Arm::Fc);
        let b = simulate(&cfg, 8, 1.0, Arm::Fc);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.mean_response_s, b.mean_response_s);
        let c = simulate(&cfg, 8, 1.0, Arm::FcStream);
        let d = simulate(&cfg, 8, 1.0, Arm::FcStream);
        assert_eq!(c.completed, d.completed);
        assert_eq!(c.mean_response_s, d.mean_response_s);
    }

    #[test]
    fn stream_cumulative_bytes_beat_recompute_5x_at_128_steps() {
        // the Fig-7 byte model: 128 decode steps, cumulative uplink
        // bytes — the stream arm must undercut the FC recompute
        // regime >= 5x (and the uncompressed regime far more)
        let cfg = quick_cfg();
        let cum = |arm: Arm| -> f64 {
            (0..128).map(|t| bytes_per_step(&cfg, arm, t)).sum()
        };
        let (orig, fc, fcs) = (cum(Arm::Original), cum(Arm::Fc),
                               cum(Arm::FcStream));
        assert!(fc / fcs >= 5.0, "fc {fc:.0} vs stream {fcs:.0}");
        assert!(orig / fcs >= 40.0, "orig {orig:.0} vs stream {fcs:.0}");
        // keyframe cadence: step 0 is a full block, deltas are not
        assert_eq!(bytes_per_step(&cfg, Arm::FcStream, 0),
                   bytes_per_step(&cfg, Arm::Fc, 0));
        assert!(bytes_per_step(&cfg, Arm::FcStream, 1)
                < bytes_per_step(&cfg, Arm::Fc, 1) / 4.0);
    }

    #[test]
    fn adaptive_bytes_undercut_stream_only_in_slow_phases() {
        let cfg = quick_cfg();
        // fast phase (first adaptive_phase_steps steps): identical to
        // the plain stream arm, keyframe and delta alike
        assert_eq!(bytes_per_step(&cfg, Arm::FcAdaptive, 0),
                   bytes_per_step(&cfg, Arm::FcStream, 0));
        assert_eq!(bytes_per_step(&cfg, Arm::FcAdaptive, 3),
                   bytes_per_step(&cfg, Arm::FcStream, 3));
        // slow phase: the reduced ladder point undercuts the stream
        let slow = cfg.adaptive_phase_steps + 1; // delta inside phase 1
        assert!(bytes_per_step(&cfg, Arm::FcAdaptive, slow)
                    < bytes_per_step(&cfg, Arm::FcStream, slow));
        // cumulative over a horizon with both phases: adaptive wins
        let cum = |arm: Arm| -> f64 {
            (0..128).map(|t| bytes_per_step(&cfg, arm, t)).sum()
        };
        let (fcs, fca) = (cum(Arm::FcStream), cum(Arm::FcAdaptive));
        assert!(fca < fcs, "adaptive {fca:.0} vs stream {fcs:.0}");
        // the DES runs it end to end deterministically
        let a = simulate(&cfg, 8, 1.0, Arm::FcAdaptive);
        let b = simulate(&cfg, 8, 1.0, Arm::FcAdaptive);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.mean_response_s, b.mean_response_s);
        assert!(a.completed > 0);
    }

    #[test]
    fn chunked_prefill_prompt_bytes_undercut_monolithic_2x() {
        // the PR-10 headline: chunked prefill must undercut the
        // monolithic keyframe >= 2x on prompt-phase wire bytes
        let cfg = quick_cfg();
        let orig = prompt_bytes(&cfg, Arm::Original);
        let mono = prompt_bytes(&cfg, Arm::Fc);
        let chunked = prompt_bytes(&cfg, Arm::FcStream);
        assert!(mono / chunked >= 2.0,
                "mono {mono:.0} vs chunked {chunked:.0}");
        assert!(orig > mono);
        // deterministic, and the streaming arms share the model
        assert_eq!(chunked, prompt_bytes(&cfg, Arm::FcStream));
        assert_eq!(chunked, prompt_bytes(&cfg, Arm::FcAdaptive));
        // degenerate single-chunk split collapses to ~the monolithic
        // plane (one keyframe chunk + one header)
        let mut one = quick_cfg();
        one.prefill_chunks = 1;
        let pb = prompt_bytes(&one, Arm::FcStream);
        assert!((pb - mono - super::PREFILL_HEADER_BYTES as f64).abs() < 1e-6,
                "single-chunk {pb:.0} vs mono {mono:.0}");
    }

    #[test]
    fn stream_beats_fc_when_bandwidth_bound() {
        // a link slow enough that the FC recompute regime saturates it
        // (offered load > 1) while the delta stream stays comfortable
        let mut cfg = quick_cfg();
        cfg.compute_units = 8; // ample compute: link is the bottleneck
        cfg.link_gbps = vec![0.05];
        let fc = simulate(&cfg, 32, 0.05, Arm::Fc);
        let fcs = simulate(&cfg, 32, 0.05, Arm::FcStream);
        assert!(fcs.mean_response_s < fc.mean_response_s * 0.5,
                "stream {} fc {}", fcs.mean_response_s, fc.mean_response_s);
        assert!(fcs.link_util < fc.link_util);
    }
}
