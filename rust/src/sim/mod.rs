//! Discrete-event simulator for the multi-client scalability study
//! (Fig 7).  Models N closed-loop clients sharing one uplink and an
//! edge server with `compute_units` parallel accelerators.
//!
//! Per request (one "conversation turn" of `output_tokens` decode
//! steps under the paper's recompute regime):
//!   client think → [per step: compress + uplink transfer of the
//!   (growing) activation + server queueing + compute] → response.
//! The uplink is a shared FIFO resource, the server a `k`-server
//! queue — exactly the two bottlenecks Fig 7 contrasts.

pub mod des;

use crate::config::SimConfig;
use crate::util::json::Json;
use crate::util::rng::Rng;
use des::{EventQueue, Resource};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arm {
    /// uncompressed activations
    Original,
    /// FourierCompress at `fc_ratio`
    Fc,
}

#[derive(Debug, Clone)]
pub struct RunStats {
    pub clients: usize,
    pub link_gbps: f64,
    pub completed: usize,
    pub mean_response_s: f64,
    pub p95_response_s: f64,
    pub server_util: f64,
    pub link_util: f64,
}

/// Simulate one (clients, link, arm) cell of Fig 7.
pub fn simulate(cfg: &SimConfig, clients: usize, link_gbps: f64, arm: Arm)
    -> RunStats {
    let mut rng = Rng::new(cfg.seed ^ (clients as u64) << 8
                           ^ (link_gbps as u64) << 24
                           ^ if arm == Arm::Fc { 1 } else { 0 });
    let mut q = EventQueue::new();
    let mut link = Resource::new(1);
    let mut server = Resource::new(cfg.compute_units);

    // per-step activation bytes: recompute regime — step t transmits
    // the full (prompt + t tokens) × hidden fp32 activation
    let bytes_at = |step: usize| -> f64 {
        let toks = cfg.prompt_tokens + step;
        let raw = (toks * cfg.hidden * 4) as f64;
        match arm {
            Arm::Original => raw,
            Arm::Fc => raw / cfg.fc_ratio,
        }
    };
    // compression cost on the device (hardware-accelerated FC is
    // sub-ms; it shows up in Fig 6, not here, but we keep it honest)
    let compress_s = match arm {
        Arm::Original => 0.0,
        Arm::Fc => 1.0e-4,
    };
    let link_rate = link_gbps * 1e9 / 8.0; // bytes/s

    // state per in-flight request
    #[derive(Clone)]
    struct Req {
        t_start: f64,
        step: usize,
    }
    let mut reqs: Vec<Option<Req>> = vec![None; clients];
    let mut responses: Vec<f64> = Vec::new();
    let mut link_busy = 0.0f64;
    let mut server_busy = 0.0f64;

    // event kinds
    const THINK_DONE: u32 = 0;
    const LINK_GRANT: u32 = 1;
    const LINK_DONE: u32 = 2;
    const SERVER_GRANT: u32 = 3;
    const SERVER_DONE: u32 = 4;

    for c in 0..clients {
        q.schedule(rng.exp(1.0 / cfg.think_time_s), THINK_DONE, c as u64);
    }

    let service_s = cfg.service_per_token_s;
    while let Some(ev) = q.pop() {
        if ev.time > cfg.horizon_s {
            break;
        }
        let c = ev.payload as usize;
        match ev.kind {
            THINK_DONE => {
                reqs[c] = Some(Req { t_start: ev.time, step: 0 });
                link.request(&mut q, ev.time, LINK_GRANT, c as u64);
            }
            LINK_GRANT => {
                let step = reqs[c].as_ref().map(|r| r.step).unwrap_or(0);
                let dt = compress_s + bytes_at(step) / link_rate;
                link_busy += dt;
                q.schedule(ev.time + dt, LINK_DONE, c as u64);
            }
            LINK_DONE => {
                link.release(&mut q, ev.time);
                server.request(&mut q, ev.time, SERVER_GRANT, c as u64);
            }
            SERVER_GRANT => {
                // one decode step: prefix recompute + next-token
                let step = reqs[c].as_ref().map(|r| r.step).unwrap_or(0);
                let toks = cfg.prompt_tokens + step;
                let dt = service_s * (1.0 + toks as f64 / cfg.prompt_tokens as f64);
                server_busy += dt;
                q.schedule(ev.time + dt, SERVER_DONE, c as u64);
            }
            SERVER_DONE => {
                server.release(&mut q, ev.time);
                let done = {
                    let r = reqs[c].as_mut().unwrap();
                    r.step += 1;
                    r.step >= cfg.output_tokens
                };
                if done {
                    let r = reqs[c].take().unwrap();
                    responses.push(ev.time - r.t_start);
                    q.schedule(ev.time + rng.exp(1.0 / cfg.think_time_s),
                               THINK_DONE, c as u64);
                } else {
                    link.request(&mut q, ev.time, LINK_GRANT, c as u64);
                }
            }
            _ => unreachable!(),
        }
    }

    responses.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = responses.len();
    let mean = if n > 0 { responses.iter().sum::<f64>() / n as f64 } else { f64::NAN };
    let p95 = if n > 0 { responses[(n as f64 * 0.95) as usize % n] } else { f64::NAN };
    RunStats {
        clients,
        link_gbps,
        completed: n,
        mean_response_s: mean,
        p95_response_s: p95,
        server_util: server_busy / (cfg.horizon_s * cfg.compute_units as f64),
        link_util: link_busy / cfg.horizon_s,
    }
}

/// The full Fig-7 sweep: clients × link rates × {Original, FC}.
pub fn fig7(cfg: &SimConfig) -> Json {
    let mut out = Json::obj();
    out.set("compute_units", Json::Num(cfg.compute_units as f64));
    out.set("fc_ratio", Json::Num(cfg.fc_ratio));
    out.set("clients",
            Json::Arr(cfg.clients.iter().map(|&c| Json::Num(c as f64)).collect()));
    for &g in &cfg.link_gbps {
        for (arm, tag) in [(Arm::Original, "orig"), (Arm::Fc, "fc")] {
            let mut means = Vec::new();
            let mut utils = Vec::new();
            for &c in &cfg.clients {
                let st = simulate(cfg, c, g, arm);
                means.push(Json::Num((st.mean_response_s * 1000.0).round() / 1000.0));
                utils.push(Json::Num((st.server_util * 1000.0).round() / 1000.0));
            }
            out.set(&format!("{tag}_{g}gbps_mean_s"), Json::Arr(means));
            out.set(&format!("{tag}_{g}gbps_server_util"), Json::Arr(utils));
        }
        crate::info!("fig7", "link {g} Gbps done");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SimConfig {
        SimConfig {
            clients: vec![4],
            link_gbps: vec![1.0],
            compute_units: 1,
            think_time_s: 0.5,
            output_tokens: 8,
            prompt_tokens: 32,
            hidden: 2048,
            fc_ratio: 10.0,
            service_per_token_s: 0.002,
            horizon_s: 60.0,
            seed: 1,
        }
    }

    #[test]
    fn completes_requests() {
        let st = simulate(&quick_cfg(), 4, 1.0, Arm::Fc);
        assert!(st.completed > 10, "completed {}", st.completed);
        assert!(st.mean_response_s > 0.0);
    }

    #[test]
    fn fc_beats_original_when_bandwidth_bound() {
        let mut cfg = quick_cfg();
        cfg.compute_units = 8; // ample compute: link is the bottleneck
        cfg.link_gbps = vec![0.2];
        let orig = simulate(&cfg, 32, 0.2, Arm::Original);
        let fc = simulate(&cfg, 32, 0.2, Arm::Fc);
        assert!(fc.mean_response_s < orig.mean_response_s * 0.5,
                "fc {} orig {}", fc.mean_response_s, orig.mean_response_s);
    }

    #[test]
    fn link_speed_irrelevant_when_compute_bound() {
        // Fig 7(a): single unit saturated by many clients
        let mut cfg = quick_cfg();
        cfg.compute_units = 1;
        let slow = simulate(&cfg, 64, 1.0, Arm::Fc);
        let fast = simulate(&cfg, 64, 10.0, Arm::Fc);
        let rel = (slow.mean_response_s - fast.mean_response_s).abs()
            / slow.mean_response_s;
        assert!(rel < 0.25, "rel diff {rel}");
        assert!(slow.server_util > 0.9, "util {}", slow.server_util);
    }

    #[test]
    fn more_clients_more_latency_at_saturation() {
        let cfg = quick_cfg();
        let a = simulate(&cfg, 16, 1.0, Arm::Original);
        let b = simulate(&cfg, 128, 1.0, Arm::Original);
        assert!(b.mean_response_s > a.mean_response_s);
    }

    #[test]
    fn deterministic() {
        let cfg = quick_cfg();
        let a = simulate(&cfg, 8, 1.0, Arm::Fc);
        let b = simulate(&cfg, 8, 1.0, Arm::Fc);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.mean_response_s, b.mean_response_s);
    }
}
