//! Live calibration probe: drive the *real* serving core (sessions,
//! batcher, workers, codec engines) through an in-proc transport —
//! zero sockets — and record per-step wire bytes, so the DES byte
//! model ([`super::bytes_per_step`]) can be audited against the live
//! stack instead of trusted.
//!
//! The DES abstracts a decode step to "bytes over a shared link";
//! this module produces those bytes from an actual
//! `DeviceClient`/`ServingService` exchange over
//! [`crate::coordinator::InProcTransport`], per step and per regime
//! (recompute vs spectral delta stream).

use crate::codec::stream::StreamConfig;
use crate::config::ServeConfig;
use crate::coordinator::{start_service, DeviceClient};
use crate::model::tokenizer;
use crate::runtime::ArtifactStore;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// One decode step as observed on the wire.
#[derive(Debug, Clone, Copy)]
pub struct LiveStep {
    /// Uplink bytes this step cost (frame overhead + header + body).
    pub wire_bytes: u64,
    /// Whether the step went out as a stream keyframe (always false
    /// in the recompute regime).
    pub keyframe: bool,
}

/// A measured generation: per-step wire bytes plus the tokens it
/// produced (so regimes can be checked for semantic parity, not just
/// byte counts).
#[derive(Debug, Clone)]
pub struct LiveTrace {
    pub steps: Vec<LiveStep>,
    pub key_frames: u64,
    pub delta_frames: u64,
    pub tokens: Vec<i32>,
}

impl LiveTrace {
    pub fn total_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.wire_bytes).sum()
    }
}

/// Run `steps` decode steps against the real serving core over an
/// in-proc link and return the per-step wire accounting.  `stream`
/// switches the spectral delta regime on (the server must advertise
/// the stream capability).  The service is started and shut down
/// inside the call — the probe is hermetic and socket-free.
pub fn trace_serving_bytes(cfg: &ServeConfig, store: Arc<ArtifactStore>,
                           prompt: &str, steps: usize,
                           stream: Option<StreamConfig>) -> Result<LiveTrace> {
    let handle = start_service(cfg, store.clone())?;
    let transport = handle.connect_inproc();
    let mut client = DeviceClient::connect_over(Box::new(transport), &store, 1)?;
    if let Some(sc) = stream {
        ensure!(client.enable_stream(sc),
                "server did not advertise the stream capability");
    }

    let mut ctx = tokenizer::encode_prompt(prompt);
    let mut trace = LiveTrace {
        steps: Vec::with_capacity(steps),
        key_frames: 0,
        delta_frames: 0,
        tokens: Vec::with_capacity(steps),
    };
    let mut last_bytes = client.stats.bytes_sent;
    let mut last_keys = client.stats.key_frames;
    for _ in 0..steps {
        let (token, _lp) = client.step(&ctx)?;
        ctx.push(token);
        trace.tokens.push(token);
        trace.steps.push(LiveStep {
            wire_bytes: client.stats.bytes_sent - last_bytes,
            keyframe: client.stats.key_frames > last_keys,
        });
        last_bytes = client.stats.bytes_sent;
        last_keys = client.stats.key_frames;
    }
    trace.key_frames = client.stats.key_frames;
    trace.delta_frames = client.stats.delta_frames;
    client.bye()?;
    drop(client);
    handle.shutdown();
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{ACTIVATION_HEADER_BYTES,
                                       FRAME_OVERHEAD_BYTES,
                                       STREAM_HEADER_BYTES};
    use crate::testkit::forged_store;

    // short prompt: BOS + 9 bytes = 10 tokens, +4 steps stays inside
    // the forged 16-token bucket, so every step ships the same block
    const PROMPT: &str = "Q rok ? A";
    const STEPS: usize = 4;

    fn bucket16_block(store: &ArtifactStore) -> usize {
        let b = store.manifest.path("serving.buckets.16").unwrap();
        b.usize_or("ks", 0) * b.usize_or("kd", 0)
    }

    #[test]
    fn recompute_steps_cost_exactly_one_activation_frame() {
        let store = Arc::new(forged_store("sim_live_rc").unwrap());
        let n = bucket16_block(&store);
        assert!(n > 0, "forged manifest must carry bucket 16 geometry");
        let trace = trace_serving_bytes(&ServeConfig::default(), store.clone(),
                                        PROMPT, STEPS, None).unwrap();
        let want = (FRAME_OVERHEAD_BYTES + ACTIVATION_HEADER_BYTES + n * 4)
            as u64;
        for (i, s) in trace.steps.iter().enumerate() {
            assert!(!s.keyframe);
            assert_eq!(s.wire_bytes, want,
                       "step {i}: the live wire cost must equal the \
                        Activation frame size the DES model charges");
        }
        assert_eq!(trace.key_frames + trace.delta_frames, 0);
    }

    #[test]
    fn lossless_stream_trace_is_token_identical_to_recompute() {
        let store = Arc::new(forged_store("sim_live_st").unwrap());
        let n = bucket16_block(&store);
        let base = trace_serving_bytes(&ServeConfig::default(), store.clone(),
                                       PROMPT, STEPS, None).unwrap();
        // zero drift threshold: every changed coefficient is replaced
        // exactly (sparse delta or dense-change keyframe fallback), so
        // token parity with the recompute regime is exact
        let sc = StreamConfig { keyframe_interval: 1024,
                                drift_threshold: 0.0 };
        let stream = trace_serving_bytes(&ServeConfig::default(),
                                         store.clone(), PROMPT, STEPS,
                                         Some(sc)).unwrap();
        assert_eq!(stream.tokens, base.tokens,
                   "stream regime diverged from recompute");
        assert!(stream.steps[0].keyframe, "first stream step is a keyframe");
        let key_bytes = (FRAME_OVERHEAD_BYTES + STREAM_HEADER_BYTES + n * 4)
            as u64;
        assert_eq!(stream.steps[0].wire_bytes, key_bytes);
    }

    #[test]
    fn delta_regime_undercuts_recompute_bytes() {
        let store = Arc::new(forged_store("sim_live_dl").unwrap());
        let n = bucket16_block(&store);
        let base = trace_serving_bytes(&ServeConfig::default(), store.clone(),
                                       PROMPT, STEPS, None).unwrap();
        // a high threshold keeps every post-keyframe step in the delta
        // regime regardless of how much the activation moves (the
        // regime the DES's `stream_delta_fill` column models)
        let sc = StreamConfig { keyframe_interval: 1024,
                                drift_threshold: 0.9 };
        let stream = trace_serving_bytes(&ServeConfig::default(),
                                         store.clone(), PROMPT, STEPS,
                                         Some(sc)).unwrap();
        let key_bytes = (FRAME_OVERHEAD_BYTES + STREAM_HEADER_BYTES + n * 4)
            as u64;
        assert!(stream.steps[0].keyframe);
        for (i, s) in stream.steps.iter().enumerate().skip(1) {
            assert!(!s.keyframe, "step {i} re-keyed inside the bucket");
            assert!(s.wire_bytes < key_bytes,
                    "delta step {i} ({} B) must undercut a keyframe \
                     ({key_bytes} B)", s.wire_bytes);
        }
        assert_eq!(stream.key_frames, 1);
        assert_eq!(stream.delta_frames as usize, STEPS - 1);
        assert!(stream.total_bytes() < base.total_bytes(),
                "stream {} B vs recompute {} B", stream.total_bytes(),
                base.total_bytes());
    }
}
