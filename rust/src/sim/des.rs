//! Discrete-event machinery: a time-ordered event queue and a FIFO
//! k-server resource.  Deterministic: ties break by insertion order.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time: f64,
    pub kind: u32,
    pub payload: u64,
    seq: u64,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: earlier time first; FIFO within a timestamp
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn schedule(&mut self, time: f64, kind: u32, payload: u64) {
        self.seq += 1;
        self.heap.push(Event { time, kind, payload, seq: self.seq });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// k-server FIFO resource: `request` either fires the grant event now
/// or queues it; `release` fires the next waiter's grant.
pub struct Resource {
    capacity: usize,
    in_use: usize,
    waiters: VecDeque<(u32, u64)>,
}

impl Resource {
    pub fn new(capacity: usize) -> Resource {
        Resource { capacity, in_use: 0, waiters: VecDeque::new() }
    }

    pub fn request(&mut self, q: &mut EventQueue, now: f64, grant_kind: u32,
                   payload: u64) {
        if self.in_use < self.capacity {
            self.in_use += 1;
            q.schedule(now, grant_kind, payload);
        } else {
            self.waiters.push_back((grant_kind, payload));
        }
    }

    pub fn release(&mut self, q: &mut EventQueue, now: f64) {
        if let Some((kind, payload)) = self.waiters.pop_front() {
            q.schedule(now, kind, payload);
        } else {
            self.in_use = self.in_use.saturating_sub(1);
        }
    }

    pub fn queue_len(&self) -> usize {
        self.waiters.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, 0, 3);
        q.schedule(1.0, 0, 1);
        q.schedule(2.0, 0, 2);
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn ties_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(1.0, 0, i);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn resource_grants_up_to_capacity() {
        let mut q = EventQueue::new();
        let mut r = Resource::new(2);
        r.request(&mut q, 0.0, 9, 1);
        r.request(&mut q, 0.0, 9, 2);
        r.request(&mut q, 0.0, 9, 3); // queued
        assert_eq!(q.len(), 2);
        assert_eq!(r.queue_len(), 1);
        r.release(&mut q, 1.0);
        assert_eq!(q.len(), 3); // waiter granted
        assert_eq!(r.queue_len(), 0);
    }

    #[test]
    fn release_without_waiters_frees_slot() {
        let mut q = EventQueue::new();
        let mut r = Resource::new(1);
        r.request(&mut q, 0.0, 9, 1);
        r.release(&mut q, 1.0);
        r.request(&mut q, 2.0, 9, 2); // should grant immediately
        assert_eq!(q.len(), 2);
    }
}
