//! Config system: typed configs with defaults, JSON-file loading,
//! `key=value` override strings (CLI `--set`), and validation.
//!
//! Every experiment driver and the serving coordinator read their
//! parameters through this module so runs are reproducible from a
//! single file (`configs/*.json` in the repo root are examples).

use crate::util::json::Json;
use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP bind address for the edge server.
    pub listen: String,
    /// Model name from the manifest.
    pub model: String,
    /// Path to the artifacts directory.
    pub artifacts: String,
    /// Number of simulated accelerator compute units (execution
    /// permits) — 1 for Fig 7(a), 8 for Fig 7(b).
    pub compute_units: usize,
    /// Max requests folded into one server batch.
    pub max_batch: usize,
    /// Batch flush deadline in microseconds.
    pub batch_deadline_us: u64,
    /// Codec applied on the wire ("fc", "topk", "none", ...).
    pub codec: String,
    /// Target compression ratio.
    pub ratio: f64,
    /// Simulated link bandwidth in Gbps (0 = unlimited / real TCP only).
    pub link_gbps: f64,
    /// Simulated one-way link latency in microseconds.
    pub link_latency_us: u64,
    /// Session KV/state eviction TTL in seconds.
    pub session_ttl_s: u64,
    /// Advertise the spectral-delta-stream capability in the
    /// handshake.  `false` makes v2 clients downgrade cleanly to the
    /// recompute regime (and rejects raw Delta frames) — the
    /// capability-negotiation lever.
    pub stream: bool,
    /// Advertise the adaptive rate-control capability + full bucket
    /// quality ladders in the handshake (`codec::rate`).  `false`
    /// truncates the advert to the primary point and rejects data
    /// frames at non-primary ladder points — clients downgrade
    /// cleanly to the paper's fixed block.
    pub ladder: bool,
    /// Advertise the lossless entropy-coding capability
    /// (`codec::wire`) in the handshake.  `false` makes
    /// entropy-capable clients downgrade cleanly to raw payloads (and
    /// rejects coded frames) — same negotiation lever as `stream`.
    pub entropy: bool,
    /// Advertise the chunked-prefill capability (`codec::stream`
    /// prefill mode) in the handshake.  `false` makes prefill-capable
    /// clients downgrade cleanly to the monolithic prompt keyframe
    /// (and rejects `PrefillChunk` frames) — same negotiation lever
    /// as `stream`.
    pub prefill: bool,
    /// Session-table shards.  Session state is partitioned by a hash
    /// of the session id into this many independently-locked
    /// `SessionManager` shards, so the serving data path never takes
    /// a global session lock.
    pub shards: usize,
    /// Poll-loop worker threads.  Connections are multiplexed over
    /// this fixed pool via non-blocking `try_recv` readiness instead
    /// of one blocking thread per connection.
    pub poll_workers: usize,
    /// Per-connection idle deadline in milliseconds: a connection
    /// that sends nothing for this long is disconnected by the poll
    /// loop (`idle_disconnects` metric).  0 disables the deadline.
    pub idle_deadline_ms: u64,
    /// Observability snapshot cadence in milliseconds: a background
    /// tick emits one delta-metrics JSONL line per interval
    /// (`ServiceHandle::snapshots`).  0 disables the tick entirely.
    pub snapshot_interval_ms: u64,
    /// Per-step trace sampling divisor: trace every step whose span
    /// id is ≡ 0 mod this value (1 = every step).  0 disables
    /// tracing — the hot path then pays one atomic load + branch.
    pub trace_sample: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            listen: "127.0.0.1:7433".into(),
            model: "llamette-m".into(),
            artifacts: "artifacts".into(),
            compute_units: 1,
            max_batch: 4,
            batch_deadline_us: 2000,
            codec: "fc".into(),
            ratio: 8.0,
            link_gbps: 0.0,
            link_latency_us: 0,
            session_ttl_s: 300,
            stream: true,
            ladder: true,
            entropy: true,
            prefill: true,
            shards: 8,
            poll_workers: 4,
            idle_deadline_ms: 30_000,
            snapshot_interval_ms: 0,
            trace_sample: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct EvalConfig {
    pub artifacts: String,
    pub models: Vec<String>,
    pub datasets: Vec<String>,
    pub methods: Vec<String>,
    pub ratios: Vec<f64>,
    pub split_layers: Vec<usize>,
    pub max_items: usize,
    pub out: String,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            artifacts: "artifacts".into(),
            models: vec![],   // empty = all in manifest
            datasets: vec![], // empty = all in manifest
            methods: vec!["fc".into(), "topk".into(), "qr".into(),
                          "fwsvd".into(), "asvd".into(), "svdllm".into()],
            ratios: vec![6.0, 7.0, 8.0, 9.0, 10.0],
            split_layers: vec![1],
            max_items: 192,
            out: "results".into(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Client counts to sweep.
    pub clients: Vec<usize>,
    /// Link rates (Gbps) to sweep.
    pub link_gbps: Vec<f64>,
    /// Server compute units (1 = Fig 7a, 8 = Fig 7b).
    pub compute_units: usize,
    /// Mean think time between client requests (s).
    pub think_time_s: f64,
    /// Tokens generated per request (drives activation bytes).
    pub output_tokens: usize,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Activation hidden size (paper uses Llama 3 on PIQA).
    pub hidden: usize,
    /// Compression ratio for the FC arm (payload divider).
    pub fc_ratio: f64,
    /// `Arm::FcStream`: decode steps between forced keyframes.
    pub stream_keyframe_interval: usize,
    /// `Arm::FcStream`: fraction of the block's coefficients a delta
    /// step retransmits (at 8 wire bytes each — u32 index + f32
    /// value; see `sim::bytes_per_step`).
    pub stream_delta_fill: f64,
    /// Chunked prefill (`codec::stream` prefill mode): number of
    /// fixed-row chunks the prompt-phase plane is split into — one
    /// keyframe chunk plus `prefill_chunks - 1` row-delta chunks
    /// (see `sim::prompt_bytes`).
    pub prefill_chunks: usize,
    /// Chunked prefill: fraction of a delta chunk's coefficients the
    /// Parseval-bounded budget actually retransmits (at 8 wire bytes
    /// each — u32 index + f32 value).
    pub prefill_delta_fill: f64,
    /// `Arm::FcAdaptive`: length (in decode steps) of each phase of
    /// the built-in fluctuating-link trace — fast and slow phases
    /// alternate.
    pub adaptive_phase_steps: usize,
    /// `Arm::FcAdaptive`: fraction of the block the reduced ladder
    /// point keeps during slow phases (1.0 = never downshifts).
    pub adaptive_low_fill: f64,
    /// Per-token server compute time on one unit (s).
    pub service_per_token_s: f64,
    /// Simulated duration (s).
    pub horizon_s: f64,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            clients: vec![1, 10, 25, 50, 100, 150, 250, 500, 1000, 1500, 2000],
            link_gbps: vec![1.0, 3.0, 5.0, 10.0],
            compute_units: 8,
            think_time_s: 1.0,
            output_tokens: 16,
            prompt_tokens: 32,
            hidden: 2048,
            fc_ratio: 10.3,
            stream_keyframe_interval: 32,
            stream_delta_fill: 0.05,
            prefill_chunks: 16,
            prefill_delta_fill: 0.05,
            adaptive_phase_steps: 16,
            adaptive_low_fill: 0.35,
            // calibrated so a fully-batched 8-unit server is NOT the
            // bottleneck below ~2000 clients (Fig 7b); the 1-unit
            // regime (Fig 7a) overrides this to 4e-3 (unbatched
            // single-accelerator saturating around 10 clients, as in
            // the paper) — see rust/benches/fig7.rs.
            service_per_token_s: 1.2e-4,
            horizon_s: 120.0,
            seed: 42,
        }
    }
}

// ---------------------------------------------------------------------------
// loading / overrides
// ---------------------------------------------------------------------------

pub trait FromJson: Default {
    fn apply_json(&mut self, j: &Json) -> Result<()>;
    fn apply_override(&mut self, key: &str, value: &str) -> Result<()>;
    fn validate(&self) -> Result<()>;

    fn load(path: Option<&str>, overrides: &[String]) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)?;
            let j = crate::util::json::parse(&text)?;
            cfg.apply_json(&j)?;
        }
        for ov in overrides {
            let (k, v) = ov
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override '{ov}' must be key=value"))?;
            cfg.apply_override(k.trim(), v.trim())?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

fn parse_list_f64(v: &str) -> Result<Vec<f64>> {
    v.split(',').map(|s| Ok(s.trim().parse::<f64>()?)).collect()
}

fn parse_list_usize(v: &str) -> Result<Vec<usize>> {
    v.split(',').map(|s| Ok(s.trim().parse::<usize>()?)).collect()
}

fn parse_list_str(v: &str) -> Vec<String> {
    v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
}

impl FromJson for ServeConfig {
    fn apply_json(&mut self, j: &Json) -> Result<()> {
        self.listen = j.str_or("listen", &self.listen);
        self.model = j.str_or("model", &self.model);
        self.artifacts = j.str_or("artifacts", &self.artifacts);
        self.compute_units = j.usize_or("compute_units", self.compute_units);
        self.max_batch = j.usize_or("max_batch", self.max_batch);
        self.batch_deadline_us =
            j.f64_or("batch_deadline_us", self.batch_deadline_us as f64) as u64;
        self.codec = j.str_or("codec", &self.codec);
        self.ratio = j.f64_or("ratio", self.ratio);
        self.link_gbps = j.f64_or("link_gbps", self.link_gbps);
        self.link_latency_us =
            j.f64_or("link_latency_us", self.link_latency_us as f64) as u64;
        self.session_ttl_s = j.f64_or("session_ttl_s", self.session_ttl_s as f64) as u64;
        if let Some(b) = j.get("stream").and_then(|v| v.as_bool()) {
            self.stream = b;
        }
        if let Some(b) = j.get("ladder").and_then(|v| v.as_bool()) {
            self.ladder = b;
        }
        if let Some(b) = j.get("entropy").and_then(|v| v.as_bool()) {
            self.entropy = b;
        }
        if let Some(b) = j.get("prefill").and_then(|v| v.as_bool()) {
            self.prefill = b;
        }
        self.shards = j.usize_or("shards", self.shards);
        self.poll_workers = j.usize_or("poll_workers", self.poll_workers);
        self.idle_deadline_ms =
            j.f64_or("idle_deadline_ms", self.idle_deadline_ms as f64) as u64;
        self.snapshot_interval_ms =
            j.f64_or("snapshot_interval_ms", self.snapshot_interval_ms as f64) as u64;
        self.trace_sample =
            j.f64_or("trace_sample", self.trace_sample as f64) as u64;
        Ok(())
    }

    fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "listen" => self.listen = value.into(),
            "model" => self.model = value.into(),
            "artifacts" => self.artifacts = value.into(),
            "compute_units" => self.compute_units = value.parse()?,
            "max_batch" => self.max_batch = value.parse()?,
            "batch_deadline_us" => self.batch_deadline_us = value.parse()?,
            "codec" => self.codec = value.into(),
            "ratio" => self.ratio = value.parse()?,
            "link_gbps" => self.link_gbps = value.parse()?,
            "link_latency_us" => self.link_latency_us = value.parse()?,
            "session_ttl_s" => self.session_ttl_s = value.parse()?,
            "stream" => self.stream = value.parse()?,
            "ladder" => self.ladder = value.parse()?,
            "entropy" => self.entropy = value.parse()?,
            "prefill" => self.prefill = value.parse()?,
            "shards" => self.shards = value.parse()?,
            "poll_workers" => self.poll_workers = value.parse()?,
            "idle_deadline_ms" => self.idle_deadline_ms = value.parse()?,
            "snapshot_interval_ms" => self.snapshot_interval_ms = value.parse()?,
            "trace_sample" => self.trace_sample = value.parse()?,
            _ => bail!("unknown ServeConfig key '{key}'"),
        }
        Ok(())
    }

    fn validate(&self) -> Result<()> {
        if self.compute_units == 0 {
            bail!("compute_units must be >= 1");
        }
        if self.max_batch == 0 || self.max_batch > 64 {
            bail!("max_batch must be in 1..=64");
        }
        if self.ratio < 1.0 {
            bail!("ratio must be >= 1");
        }
        if self.shards == 0 || self.shards > 1024 {
            bail!("shards must be in 1..=1024");
        }
        if self.poll_workers == 0 || self.poll_workers > 256 {
            bail!("poll_workers must be in 1..=256");
        }
        if self.snapshot_interval_ms > 60_000 {
            bail!("snapshot_interval_ms must be <= 60000 (0 = off)");
        }
        Ok(())
    }
}

impl FromJson for EvalConfig {
    fn apply_json(&mut self, j: &Json) -> Result<()> {
        self.artifacts = j.str_or("artifacts", &self.artifacts);
        if let Some(a) = j.get("models").and_then(|v| v.as_arr()) {
            self.models = a.iter().filter_map(|v| v.as_str().map(String::from)).collect();
        }
        if let Some(a) = j.get("datasets").and_then(|v| v.as_arr()) {
            self.datasets = a.iter().filter_map(|v| v.as_str().map(String::from)).collect();
        }
        if let Some(a) = j.get("methods").and_then(|v| v.as_arr()) {
            self.methods = a.iter().filter_map(|v| v.as_str().map(String::from)).collect();
        }
        if let Some(a) = j.get("ratios").and_then(|v| v.as_arr()) {
            self.ratios = a.iter().filter_map(|v| v.as_f64()).collect();
        }
        if let Some(a) = j.get("split_layers").and_then(|v| v.as_arr()) {
            self.split_layers = a.iter().filter_map(|v| v.as_usize()).collect();
        }
        self.max_items = j.usize_or("max_items", self.max_items);
        self.out = j.str_or("out", &self.out);
        Ok(())
    }

    fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "artifacts" => self.artifacts = value.into(),
            "models" => self.models = parse_list_str(value),
            "datasets" => self.datasets = parse_list_str(value),
            "methods" => self.methods = parse_list_str(value),
            "ratios" => self.ratios = parse_list_f64(value)?,
            "split_layers" => self.split_layers = parse_list_usize(value)?,
            "max_items" => self.max_items = value.parse()?,
            "out" => self.out = value.into(),
            _ => bail!("unknown EvalConfig key '{key}'"),
        }
        Ok(())
    }

    fn validate(&self) -> Result<()> {
        if self.max_items == 0 {
            bail!("max_items must be > 0");
        }
        if self.ratios.iter().any(|&r| r < 1.0) {
            bail!("ratios must be >= 1");
        }
        Ok(())
    }
}

impl FromJson for SimConfig {
    fn apply_json(&mut self, j: &Json) -> Result<()> {
        if let Some(a) = j.get("clients").and_then(|v| v.as_arr()) {
            self.clients = a.iter().filter_map(|v| v.as_usize()).collect();
        }
        if let Some(a) = j.get("link_gbps").and_then(|v| v.as_arr()) {
            self.link_gbps = a.iter().filter_map(|v| v.as_f64()).collect();
        }
        self.compute_units = j.usize_or("compute_units", self.compute_units);
        self.think_time_s = j.f64_or("think_time_s", self.think_time_s);
        self.output_tokens = j.usize_or("output_tokens", self.output_tokens);
        self.prompt_tokens = j.usize_or("prompt_tokens", self.prompt_tokens);
        self.hidden = j.usize_or("hidden", self.hidden);
        self.fc_ratio = j.f64_or("fc_ratio", self.fc_ratio);
        self.stream_keyframe_interval =
            j.usize_or("stream_keyframe_interval", self.stream_keyframe_interval);
        self.stream_delta_fill =
            j.f64_or("stream_delta_fill", self.stream_delta_fill);
        self.prefill_chunks = j.usize_or("prefill_chunks", self.prefill_chunks);
        self.prefill_delta_fill =
            j.f64_or("prefill_delta_fill", self.prefill_delta_fill);
        self.adaptive_phase_steps =
            j.usize_or("adaptive_phase_steps", self.adaptive_phase_steps);
        self.adaptive_low_fill =
            j.f64_or("adaptive_low_fill", self.adaptive_low_fill);
        self.service_per_token_s =
            j.f64_or("service_per_token_s", self.service_per_token_s);
        self.horizon_s = j.f64_or("horizon_s", self.horizon_s);
        self.seed = j.f64_or("seed", self.seed as f64) as u64;
        Ok(())
    }

    fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "clients" => self.clients = parse_list_usize(value)?,
            "link_gbps" => self.link_gbps = parse_list_f64(value)?,
            "compute_units" => self.compute_units = value.parse()?,
            "think_time_s" => self.think_time_s = value.parse()?,
            "output_tokens" => self.output_tokens = value.parse()?,
            "prompt_tokens" => self.prompt_tokens = value.parse()?,
            "hidden" => self.hidden = value.parse()?,
            "fc_ratio" => self.fc_ratio = value.parse()?,
            "stream_keyframe_interval" =>
                self.stream_keyframe_interval = value.parse()?,
            "stream_delta_fill" => self.stream_delta_fill = value.parse()?,
            "prefill_chunks" => self.prefill_chunks = value.parse()?,
            "prefill_delta_fill" =>
                self.prefill_delta_fill = value.parse()?,
            "adaptive_phase_steps" =>
                self.adaptive_phase_steps = value.parse()?,
            "adaptive_low_fill" => self.adaptive_low_fill = value.parse()?,
            "service_per_token_s" => self.service_per_token_s = value.parse()?,
            "horizon_s" => self.horizon_s = value.parse()?,
            "seed" => self.seed = value.parse()?,
            _ => bail!("unknown SimConfig key '{key}'"),
        }
        Ok(())
    }

    fn validate(&self) -> Result<()> {
        if self.clients.is_empty() || self.link_gbps.is_empty() {
            bail!("clients / link_gbps sweeps must be non-empty");
        }
        if self.compute_units == 0 {
            bail!("compute_units must be >= 1");
        }
        if self.horizon_s <= 0.0 {
            bail!("horizon_s must be positive");
        }
        if self.stream_keyframe_interval == 0 {
            bail!("stream_keyframe_interval must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.stream_delta_fill) {
            bail!("stream_delta_fill must be in [0, 1]");
        }
        if self.prefill_chunks == 0 {
            bail!("prefill_chunks must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.prefill_delta_fill) {
            bail!("prefill_delta_fill must be in [0, 1]");
        }
        if self.adaptive_phase_steps == 0 {
            bail!("adaptive_phase_steps must be >= 1");
        }
        if self.adaptive_low_fill <= 0.0 || self.adaptive_low_fill > 1.0 {
            bail!("adaptive_low_fill must be in (0, 1]");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ServeConfig::default().validate().unwrap();
        EvalConfig::default().validate().unwrap();
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn overrides_apply() {
        let cfg = ServeConfig::load(
            None,
            &["compute_units=8".into(), "codec=topk".into(), "ratio=6.5".into()],
        )
        .unwrap();
        assert_eq!(cfg.compute_units, 8);
        assert_eq!(cfg.codec, "topk");
        assert_eq!(cfg.ratio, 6.5);
        assert!(cfg.stream, "stream capability defaults on");
        assert!(cfg.ladder, "ladder capability defaults on");
        assert!(cfg.entropy, "entropy capability defaults on");
        assert!(cfg.prefill, "prefill capability defaults on");
        let cfg = ServeConfig::load(None, &["stream=false".into(),
                                            "ladder=false".into(),
                                            "entropy=false".into(),
                                            "prefill=false".into()]).unwrap();
        assert!(!cfg.stream);
        assert!(!cfg.ladder);
        assert!(!cfg.entropy);
        assert!(!cfg.prefill);
        // the JSON path reaches the entropy + prefill knobs too
        let p = std::env::temp_dir().join("fc_cfg_entropy_test.json");
        std::fs::write(&p, r#"{"entropy": false, "prefill": false}"#).unwrap();
        let cfg = ServeConfig::load(Some(p.to_str().unwrap()), &[]).unwrap();
        assert!(!cfg.entropy);
        assert!(!cfg.prefill);
    }

    #[test]
    fn bad_override_rejected() {
        assert!(ServeConfig::load(None, &["nope=1".into()]).is_err());
        assert!(ServeConfig::load(None, &["compute_units=0".into()]).is_err());
        assert!(ServeConfig::load(None, &["malformed".into()]).is_err());
        assert!(ServeConfig::load(None, &["shards=0".into()]).is_err());
        assert!(ServeConfig::load(None, &["poll_workers=0".into()]).is_err());
    }

    #[test]
    fn serving_core_knobs() {
        let cfg = ServeConfig::default();
        assert_eq!((cfg.shards, cfg.poll_workers, cfg.idle_deadline_ms),
                   (8, 4, 30_000));
        let cfg = ServeConfig::load(None, &["shards=2".into(),
                                            "poll_workers=1".into(),
                                            "idle_deadline_ms=0".into()])
            .unwrap();
        assert_eq!((cfg.shards, cfg.poll_workers, cfg.idle_deadline_ms),
                   (2, 1, 0));
    }

    #[test]
    fn observability_knobs() {
        let cfg = ServeConfig::default();
        assert_eq!((cfg.snapshot_interval_ms, cfg.trace_sample), (0, 0),
                   "observability defaults off");
        let cfg = ServeConfig::load(None, &["snapshot_interval_ms=250".into(),
                                            "trace_sample=16".into()])
            .unwrap();
        assert_eq!((cfg.snapshot_interval_ms, cfg.trace_sample), (250, 16));
        assert!(ServeConfig::load(None, &["snapshot_interval_ms=90000".into()])
                    .is_err(),
                "snapshot cadence above 60s must be refused");
        // JSON-file path reaches the same fields
        let p = std::env::temp_dir().join("fc_cfg_obs_test.json");
        std::fs::write(&p, r#"{"snapshot_interval_ms": 100, "trace_sample": 4}"#)
            .unwrap();
        let cfg = ServeConfig::load(Some(p.to_str().unwrap()), &[]).unwrap();
        assert_eq!((cfg.snapshot_interval_ms, cfg.trace_sample), (100, 4));
    }

    #[test]
    fn json_file_load() {
        let dir = std::env::temp_dir().join("fc_cfg_test.json");
        std::fs::write(&dir, r#"{"clients": [5, 10], "fc_ratio": 9.0, "compute_units": 8}"#)
            .unwrap();
        let cfg = SimConfig::load(Some(dir.to_str().unwrap()), &[]).unwrap();
        assert_eq!(cfg.clients, vec![5, 10]);
        assert_eq!(cfg.fc_ratio, 9.0);
        assert_eq!(cfg.compute_units, 8);
        // untouched fields keep defaults
        assert_eq!(cfg.output_tokens, 16);
    }

    #[test]
    fn list_override_parsing() {
        let cfg = EvalConfig::load(None, &["ratios=6,8,10".into(),
                                           "methods=fc,topk".into()]).unwrap();
        assert_eq!(cfg.ratios, vec![6.0, 8.0, 10.0]);
        assert_eq!(cfg.methods, vec!["fc", "topk"]);
    }
}
