//! Minimal CLI argument parser (the dependency set has no clap):
//! subcommand + `--flag value` / `--flag` switches + repeated `--set
//! key=value` config overrides.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, Vec<String>>,
    switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // value-taking if the next token isn't another flag
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap().clone();
                        out.flags.entry(name.to_string()).or_default().push(v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                bail!("unexpected positional argument '{a}'");
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<String> {
        self.flags.get(name).cloned().unwrap_or_default()
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
            .unwrap()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = args("eval --items 64 --verbose --set a=1 --set b=2");
        assert_eq!(a.subcommand.as_deref(), Some("eval"));
        assert_eq!(a.usize_or("items", 0), 64);
        assert!(a.has("verbose"));
        assert_eq!(a.get_all("set"), vec!["a=1", "b=2"]);
    }

    #[test]
    fn rejects_double_positional() {
        assert!(Args::parse(&["a".into(), "b".into()]).is_err());
    }

    #[test]
    fn defaults() {
        let a = args("serve");
        assert_eq!(a.f64_or("ratio", 8.0), 8.0);
        assert_eq!(a.str_or("model", "m"), "m");
        assert!(!a.has("verbose"));
    }
}
