//! Zero-dependency utilities: JSON, deterministic RNG, logging,
//! latency histograms.

pub mod bits;
pub mod hist;
pub mod json;
pub mod log;
pub mod bench;
pub mod cli;
pub mod rng;
