//! Micro-benchmark harness (criterion isn't in the dependency set):
//! warmup + timed iterations with median/mean/min reporting, and a
//! one-shot mode for expensive cases (QR/SVD at Table-IV sizes).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!("{:36} {:>10.3?} median  {:>10.3?} mean  ({} iters)",
                self.name, self.median, self.mean, self.iters)
    }
}

/// Run `f` repeatedly: a warmup pass, then up to `max_iters`
/// iterations or `budget` wall time, whichever ends first.
pub fn bench(name: &str, max_iters: usize, budget: Duration,
             mut f: impl FnMut()) -> BenchResult {
    f(); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    for _ in 0..max_iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    times.sort();
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    let min = times[0];
    let r = BenchResult {
        name: name.to_string(),
        iters: times.len(),
        median,
        mean,
        min,
    };
    println!("{}", r.report());
    r
}

/// One-shot timing for expensive operations.
pub fn once(name: &str, f: impl FnOnce()) -> Duration {
    let t0 = Instant::now();
    f();
    let dt = t0.elapsed();
    println!("{:36} {:>10.3?} (single run)", name, dt);
    dt
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 16, Duration::from_millis(200), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 1);
        assert!(r.min <= r.median);
    }

    #[test]
    fn once_returns_duration() {
        let d = once("sleep", || std::thread::sleep(Duration::from_millis(2)));
        assert!(d >= Duration::from_millis(2));
    }
}
