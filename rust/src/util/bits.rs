//! Bit-level I/O for the entropy-coded wire format (`codec::wire`):
//! an LSB-first [`BitWriter`]/[`BitReader`] pair with the unary,
//! Elias-gamma, and Golomb-Rice integer codes built on top.
//!
//! Bit order is LSB-first: the first bit written lands in the
//! least-significant bit of the first byte, so multi-bit fields can
//! straddle byte boundaries without the reader knowing widths in
//! advance.  The reader treats truncated input as a typed error,
//! never a panic — these decoders sit behind `Frame::decode` on
//! attacker-controlled bytes.

use anyhow::{ensure, Result};

/// Append-only bit stream writer.  `finish` zero-pads the last
/// partial byte, so a decoder must track its own element count rather
/// than reading to exhaustion.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    cur: u8,
    /// Filled bits of `cur`, always 0..8.
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> BitWriter {
        BitWriter::default()
    }

    /// Total bits written so far (before padding).
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Bytes the stream will occupy once finished (padding included).
    pub fn byte_len(&self) -> usize {
        self.bit_len().div_ceil(8)
    }

    /// Append the low `n` bits of `val`, LSB first.  `n` may be 0
    /// (writes nothing) up to 64 (the full word).
    pub fn write_bits(&mut self, mut val: u64, mut n: u32) {
        assert!(n <= 64, "bit width {n} > 64");
        if n < 64 {
            val &= (1u64 << n) - 1;
        }
        while n > 0 {
            let take = (8 - self.nbits).min(n);
            self.cur |= ((val & ((1u64 << take) - 1)) as u8) << self.nbits;
            self.nbits += take;
            val >>= take;
            n -= take;
            if self.nbits == 8 {
                self.buf.push(self.cur);
                self.cur = 0;
                self.nbits = 0;
            }
        }
    }

    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Unary code: `v` zero bits, then a terminating one bit.
    pub fn write_unary(&mut self, v: u64) {
        for _ in 0..v {
            self.write_bit(false);
        }
        self.write_bit(true);
    }

    /// Elias gamma (`v >= 1`): the exponent `k = floor(log2 v)` in
    /// unary, then the `k` low bits of `v` (the leading one bit is
    /// implied by the exponent).
    pub fn write_gamma(&mut self, v: u64) {
        assert!(v >= 1, "gamma is defined for v >= 1");
        let k = 63 - v.leading_zeros();
        self.write_unary(k as u64);
        self.write_bits(v, k);
    }

    /// Golomb-Rice with parameter `k`: the quotient `v >> k` in
    /// unary, then the `k` remainder bits raw.
    pub fn write_rice(&mut self, v: u64, k: u32) {
        assert!(k < 64, "rice parameter {k} out of range");
        self.write_unary(v >> k);
        self.write_bits(v, k);
    }

    /// Flush the last partial byte (zero padding) and return the
    /// stream.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push(self.cur);
        }
        self.buf
    }
}

/// Bit stream reader over a borrowed byte slice.  Every read returns
/// a typed error once the input is exhausted.
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Bit cursor into `buf`.
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> BitReader<'a> {
        BitReader { buf, pos: 0 }
    }

    /// Bits left, including any zero padding the writer flushed with.
    pub fn remaining_bits(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }

    pub fn read_bit(&mut self) -> Result<bool> {
        ensure!(self.pos < self.buf.len() * 8,
                "bitstream truncated at bit {}", self.pos);
        let b = (self.buf[self.pos / 8] >> (self.pos % 8)) & 1;
        self.pos += 1;
        Ok(b == 1)
    }

    /// Read `n` bits (0..=64), LSB first — the inverse of
    /// [`BitWriter::write_bits`].
    pub fn read_bits(&mut self, n: u32) -> Result<u64> {
        ensure!(n <= 64, "bit width {n} > 64");
        ensure!(self.remaining_bits() >= n as usize,
                "bitstream truncated at bit {} (+{n})", self.pos);
        let mut out = 0u64;
        let mut got = 0u32;
        while got < n {
            let byte = self.buf[self.pos / 8] as u64;
            let off = (self.pos % 8) as u32;
            let take = (8 - off).min(n - got);
            out |= ((byte >> off) & ((1u64 << take) - 1)) << got;
            got += take;
            self.pos += take as usize;
        }
        Ok(out)
    }

    pub fn read_unary(&mut self) -> Result<u64> {
        let mut v = 0u64;
        loop {
            if self.read_bit()? {
                return Ok(v);
            }
            v += 1;
        }
    }

    pub fn read_gamma(&mut self) -> Result<u64> {
        let k = self.read_unary()?;
        ensure!(k < 64, "gamma exponent {k} out of range");
        Ok((1u64 << k) | self.read_bits(k as u32)?)
    }

    pub fn read_rice(&mut self, k: u32) -> Result<u64> {
        ensure!(k < 64, "rice parameter {k} out of range");
        let q = self.read_unary()?;
        ensure!(k == 0 || q <= (u64::MAX >> k),
                "rice quotient {q} overflows at k={k}");
        Ok((q << k) | self.read_bits(k)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bits_roundtrip_across_byte_boundaries() {
        // widths 1..=64 written back to back so nearly every field
        // straddles a byte boundary
        let mut w = BitWriter::new();
        for n in 1..=64u32 {
            let v = 0xA5A5_5A5A_F00D_BEEFu64 >> (64 - n);
            w.write_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for n in 1..=64u32 {
            let want = 0xA5A5_5A5A_F00D_BEEFu64 >> (64 - n);
            assert_eq!(r.read_bits(n).unwrap(), want, "width {n}");
        }
        assert!(r.remaining_bits() < 8, "only padding may remain");
    }

    #[test]
    fn zero_and_full_width_edges() {
        let mut w = BitWriter::new();
        w.write_bits(0xDEAD, 0); // no-op
        assert_eq!(w.bit_len(), 0);
        w.write_bits(u64::MAX, 64);
        w.write_bits(123, 0); // no-op between fields
        w.write_bits(u64::MAX, 64);
        w.write_bit(true);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(0).unwrap(), 0);
        assert_eq!(r.read_bits(64).unwrap(), u64::MAX);
        assert_eq!(r.read_bits(64).unwrap(), u64::MAX);
        assert!(r.read_bit().unwrap());
    }

    #[test]
    fn lsb_first_layout_is_pinned() {
        // 0b1 then 0b01 then 0b111: byte 0 = 1 | (01 << 1) | (111<<3)
        let mut w = BitWriter::new();
        w.write_bits(0b1, 1);
        w.write_bits(0b01, 2);
        w.write_bits(0b111, 3);
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b0011_1011]);
    }

    #[test]
    fn unary_gamma_rice_roundtrip() {
        let vals: Vec<u64> = vec![0, 1, 2, 3, 7, 8, 63, 64, 100, 4095,
                                  1 << 20, (1 << 33) + 17];
        let mut w = BitWriter::new();
        for &v in &vals {
            if v < 200 {
                w.write_unary(v);
            }
            w.write_gamma(v + 1);
            for k in [0u32, 1, 4, 13] {
                w.write_rice(v, k);
            }
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            if v < 200 {
                assert_eq!(r.read_unary().unwrap(), v);
            }
            assert_eq!(r.read_gamma().unwrap(), v + 1);
            for k in [0u32, 1, 4, 13] {
                assert_eq!(r.read_rice(k).unwrap(), v, "rice k={k}");
            }
        }
    }

    #[test]
    fn gamma_handles_u64_extremes() {
        let mut w = BitWriter::new();
        w.write_gamma(1);
        w.write_gamma(u64::MAX);
        w.write_rice(u64::MAX, 63);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_gamma().unwrap(), 1);
        assert_eq!(r.read_gamma().unwrap(), u64::MAX);
        assert_eq!(r.read_rice(63).unwrap(), u64::MAX);
    }

    #[test]
    fn seeded_random_streams_roundtrip() {
        let mut rng = Rng::new(0xB175);
        for case in 0..200u64 {
            let mut w = BitWriter::new();
            let mut script: Vec<(u8, u64, u32)> = Vec::new();
            for _ in 0..rng.below(64) + 1 {
                match rng.below(4) {
                    0 => {
                        let n = rng.below(65) as u32;
                        let v = rng.next_u64();
                        w.write_bits(v, n);
                        let want = if n == 64 { v }
                                   else if n == 0 { 0 }
                                   else { v & ((1 << n) - 1) };
                        script.push((0, want, n));
                    }
                    1 => {
                        let v = rng.below(40) as u64;
                        w.write_unary(v);
                        script.push((1, v, 0));
                    }
                    2 => {
                        let v = rng.next_u64() >> rng.below(64) as u32 | 1;
                        w.write_gamma(v);
                        script.push((2, v, 0));
                    }
                    _ => {
                        let k = rng.below(20) as u32;
                        let v = rng.below(100_000) as u64;
                        w.write_rice(v, k);
                        script.push((3, v, k));
                    }
                }
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(op, v, n) in &script {
                let got = match op {
                    0 => r.read_bits(n).unwrap(),
                    1 => r.read_unary().unwrap(),
                    2 => r.read_gamma().unwrap(),
                    _ => r.read_rice(n).unwrap(),
                };
                assert_eq!(got, v, "case {case} op {op}");
            }
            assert!(r.remaining_bits() < 8, "case {case}: stray bytes");
        }
    }

    #[test]
    fn truncated_input_errors_instead_of_panicking() {
        let mut w = BitWriter::new();
        w.write_gamma(1 << 30);
        w.write_rice(999, 5);
        let bytes = w.finish();
        for cut in 0..bytes.len() {
            let mut r = BitReader::new(&bytes[..cut]);
            // some prefix decodes, but the stream must end in an
            // error (never a panic) before both fields come back
            let first = r.read_gamma();
            let both = first.is_ok() && r.read_rice(5).is_ok();
            assert!(!both, "cut {cut}: truncated stream decoded fully");
        }
        let mut r = BitReader::new(&[]);
        assert!(r.read_bit().is_err());
        assert!(r.read_bits(1).is_err());
        assert!(r.read_unary().is_err());
        assert!(r.read_gamma().is_err());
        assert!(r.read_rice(3).is_err());
        assert_eq!(r.read_bits(0).unwrap(), 0, "0-bit read needs no input");
    }

    #[test]
    fn all_zero_padding_never_decodes_as_unary() {
        // a unary terminator can't come from the zero padding: a
        // reader that overruns its element count hits a typed error
        let mut w = BitWriter::new();
        w.write_bit(true);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_unary().unwrap(), 0);
        assert!(r.read_unary().is_err(), "padding is all zeros");
    }

    #[test]
    fn oversized_rice_quotient_is_error() {
        // forge a stream whose unary quotient would overflow q << k
        let mut w = BitWriter::new();
        w.write_unary(3);
        w.write_bits(0, 63);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_rice(63).is_err(), "3 << 63 overflows");
    }
}
