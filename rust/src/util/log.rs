//! Tiny leveled logger.  `FC_LOG=debug|info|warn|error` selects the
//! level (default info); output goes to stderr with elapsed-time
//! stamps so request traces in the coordinator are readable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        let lvl = match std::env::var("FC_LOG").as_deref() {
            Ok("debug") => Level::Debug,
            Ok("warn") => Level::Warn,
            Ok("error") => Level::Error,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        return lvl;
    }
    match raw {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn log(lvl: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if lvl < level() {
        return;
    }
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{:9.3}s {} {}] {}", start().elapsed().as_secs_f64(), tag, target, msg);
}

#[macro_export]
macro_rules! debug { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! info { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! warn_ { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! error { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, $t, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn set_and_get() {
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }
}
