//! Tiny leveled logger with per-target filtering.  `FC_LOG` is a
//! comma-separated directive list: a bare level
//! (`debug|info|warn|error`) sets the default, and `target=level`
//! overrides it for one log target (matched by prefix, longest
//! directive winning), e.g. `FC_LOG=warn,poll=debug` silences
//! everything below warn except the poll workers.  Unrecognized
//! directives are reported once to stderr instead of being silently
//! swallowed into the info default.  Output goes to stderr with
//! elapsed-time stamps so request traces in the coordinator are
//! readable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);
static TARGETS: OnceLock<Vec<(String, Level)>> = OnceLock::new();

fn start() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

fn parse_level(s: &str) -> Option<Level> {
    match s {
        "debug" => Some(Level::Debug),
        "info" => Some(Level::Info),
        "warn" => Some(Level::Warn),
        "error" => Some(Level::Error),
        _ => None,
    }
}

/// Parse an `FC_LOG` spec into (default level, per-target directives,
/// unrecognized tokens).  Pure, so the grammar is unit-testable
/// without touching the process environment.
fn parse_spec(spec: &str) -> (Option<Level>, Vec<(String, Level)>, Vec<String>) {
    let mut default = None;
    let mut targets = Vec::new();
    let mut bad = Vec::new();
    for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        if let Some((t, l)) = tok.split_once('=') {
            match parse_level(l.trim()) {
                Some(lvl) => targets.push((t.trim().to_string(), lvl)),
                None => bad.push(tok.to_string()),
            }
        } else {
            match parse_level(tok) {
                Some(lvl) => default = Some(lvl),
                None => bad.push(tok.to_string()),
            }
        }
    }
    (default, targets, bad)
}

/// Effective level for `target` given the parsed directives: the
/// longest directive key that prefixes the target wins; no match
/// falls back to the default.
fn effective(targets: &[(String, Level)], default: Level, target: &str) -> Level {
    let mut best: Option<(usize, Level)> = None;
    for (key, lvl) in targets {
        if target.starts_with(key.as_str())
            && best.map(|(n, _)| key.len() > n).unwrap_or(true)
        {
            best = Some((key.len(), *lvl));
        }
    }
    best.map(|(_, l)| l).unwrap_or(default)
}

/// Parse `FC_LOG` exactly once (warning once about anything
/// unrecognized) and return the per-target directives.
fn directives() -> &'static [(String, Level)] {
    TARGETS.get_or_init(|| {
        let spec = std::env::var("FC_LOG").unwrap_or_default();
        let (default, targets, bad) = parse_spec(&spec);
        for tok in &bad {
            eprintln!(
                "[FC_LOG] unrecognized directive '{tok}' (expected \
                 debug|info|warn|error or target=level); using info"
            );
        }
        // an explicit set_level() that already ran wins over the env
        let _ = LEVEL.compare_exchange(
            255,
            default.unwrap_or(Level::Info) as u8,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
        targets
    })
}

/// The default log level (per-target directives may override it for
/// individual targets — see [`target_level`]).
pub fn level() -> Level {
    directives();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

/// The effective level for one log target.
pub fn target_level(target: &str) -> Level {
    let targets = directives();
    effective(targets, level(), target)
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn log(lvl: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if lvl < target_level(target) {
        return;
    }
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{:9.3}s {} {}] {}", start().elapsed().as_secs_f64(), tag, target, msg);
}

#[macro_export]
macro_rules! debug { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! info { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! warn_ { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! error { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, $t, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn set_and_get() {
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }

    #[test]
    fn spec_bare_level() {
        let (d, t, bad) = parse_spec("debug");
        assert_eq!(d, Some(Level::Debug));
        assert!(t.is_empty());
        assert!(bad.is_empty());
    }

    #[test]
    fn spec_per_target_directives() {
        let (d, t, bad) = parse_spec("warn,poll=debug, service = error");
        assert_eq!(d, Some(Level::Warn));
        assert_eq!(t, vec![("poll".to_string(), Level::Debug),
                           ("service".to_string(), Level::Error)]);
        assert!(bad.is_empty());
    }

    #[test]
    fn spec_collects_unrecognized_tokens() {
        // bad tokens are reported, good ones still apply — no silent
        // fall-through to info for the whole spec
        let (d, t, bad) = parse_spec("verbose,warn,poll=loud");
        assert_eq!(d, Some(Level::Warn));
        assert!(t.is_empty());
        assert_eq!(bad, vec!["verbose".to_string(), "poll=loud".to_string()]);
        let (d, _, bad) = parse_spec("");
        assert_eq!(d, None);
        assert!(bad.is_empty());
    }

    #[test]
    fn effective_prefix_match_longest_wins() {
        let t = vec![("poll".to_string(), Level::Debug),
                     ("serv".to_string(), Level::Error),
                     ("server".to_string(), Level::Debug)];
        assert_eq!(effective(&t, Level::Info, "poll"), Level::Debug);
        // prefix match: "serv" covers "service"...
        assert_eq!(effective(&t, Level::Info, "service"), Level::Error);
        // ...but the longer "server" directive beats it for "server"
        assert_eq!(effective(&t, Level::Info, "server"), Level::Debug);
        // no directive: the default applies
        assert_eq!(effective(&t, Level::Warn, "client"), Level::Warn);
        assert_eq!(effective(&[], Level::Info, "anything"), Level::Info);
    }
}
