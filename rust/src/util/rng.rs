//! Deterministic xoshiro256**-based RNG for workload generation and
//! the discrete-event simulator.  Reproducibility across runs matters
//! more than cryptographic quality here; every experiment seeds its
//! own stream.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Exponential with the given rate (inter-arrival times).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * std;
        }
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_covers_all() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
