//! Log-bucketed histogram (HDR-style): cheap concurrent recording in
//! the coordinator hot path, percentile queries for the benchmark
//! reports.  Buckets are powers of 2^(1/8) over [1, ~4e9], i.e. ~8.6%
//! relative precision — ample for latency reporting.
//!
//! The core API is unit-generic — [`Histogram::record`] /
//! [`Histogram::mean`] / [`Histogram::percentile`] take and return
//! plain `u64` values in whatever unit the caller chose (frames,
//! bytes, …).  Latency call sites use the `_us`-suffixed wrappers
//! ([`Histogram::record_us`], [`Histogram::record_dur`], …) so the
//! unit is visible at the call site; a frame-counting histogram like
//! the coordinator's `ladder_dwell_frames` uses the generic core and
//! no longer abuses a time-flavoured name.

use std::sync::atomic::{AtomicU64, Ordering};

const LINEAR: u64 = 256; // exact buckets below this value
const SUB: usize = 32; // sub-buckets per octave above the linear region
const OCTAVES: usize = 34;
const NBUCKETS: usize = LINEAR as usize + SUB * OCTAVES;

pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn index(v: u64) -> usize {
        if v < LINEAR {
            return v as usize;
        }
        let oct = 63 - v.leading_zeros() as usize; // floor(log2), >= 8
        let frac = ((v - (1 << oct)) * SUB as u64 >> oct) as usize;
        (LINEAR as usize + (oct - 8) * SUB + frac).min(NBUCKETS - 1)
    }

    fn bucket_value(idx: usize) -> u64 {
        if idx < LINEAR as usize {
            return idx as u64;
        }
        let r = idx - LINEAR as usize;
        let oct = 8 + r / SUB;
        let frac = (r % SUB) as u64;
        (1u64 << oct) + (frac << oct) / SUB as u64
    }

    // -- unit-generic core -------------------------------------------------

    /// Record one value (whatever unit this histogram counts).
    pub fn record(&self, v: u64) {
        self.buckets[Self::index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// p in [0, 100].
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        self.max()
    }

    // -- microsecond wrappers (latency call sites) -------------------------

    pub fn record_us(&self, us: u64) {
        self.record(us);
    }

    pub fn record_dur(&self, d: std::time::Duration) {
        self.record(d.as_micros() as u64);
    }

    pub fn mean_us(&self) -> f64 {
        self.mean()
    }

    pub fn max_us(&self) -> u64 {
        self.max()
    }

    pub fn percentile_us(&self, p: f64) -> u64 {
        self.percentile(p)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={}us p95={}us p99={}us max={}us",
            self.count(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_monotone() {
        let mut last = 0;
        for v in [1u64, 2, 3, 5, 9, 17, 100, 1000, 123_456, 10_000_000] {
            let i = Histogram::index(v);
            assert!(i >= last);
            last = i;
        }
    }

    #[test]
    fn bucket_value_brackets_input() {
        for v in [0u64, 1, 7, 63, 255, 256, 257, 1000, 4095, 1 << 20, 1 << 31] {
            let idx = Histogram::index(v);
            let lo = Histogram::bucket_value(idx);
            assert!(lo <= v, "lo {lo} v {v}");
            // next bucket must be above
            let hi = Histogram::bucket_value(idx + 1);
            assert!(hi > v, "hi {hi} v {v}");
        }
    }

    #[test]
    fn percentiles_reasonable() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(i);
        }
        let p50 = h.percentile(50.0);
        assert!((450..=560).contains(&p50), "p50={p50}");
        let p99 = h.percentile(99.0);
        assert!((900..=1100).contains(&p99), "p99={p99}");
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 500.5).abs() < 1.0);
    }

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn unit_wrappers_share_the_core() {
        // the _us wrappers are aliases over the generic core, so a
        // histogram recorded through one API reads back through the
        // other — one set of buckets, not two
        let h = Histogram::new();
        h.record_us(100);
        h.record_dur(std::time::Duration::from_micros(300));
        h.record(500); // generic unit (e.g. frames)
        assert_eq!(h.count(), 3);
        assert_eq!(h.max(), h.max_us());
        assert_eq!(h.percentile(100.0), h.percentile_us(100.0));
        assert!((h.mean() - 300.0).abs() < 1e-9);
    }
}
