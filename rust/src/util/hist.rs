//! Log-bucketed latency histogram (HDR-style): cheap concurrent
//! recording in the coordinator hot path, percentile queries for the
//! benchmark reports.  Buckets are powers of 2^(1/8) over
//! [1us, ~4000s], i.e. ~8.6% relative precision — ample for latency
//! reporting.

use std::sync::atomic::{AtomicU64, Ordering};

const LINEAR: u64 = 256; // exact buckets below this value
const SUB: usize = 32; // sub-buckets per octave above the linear region
const OCTAVES: usize = 34;
const NBUCKETS: usize = LINEAR as usize + SUB * OCTAVES;

pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn index(us: u64) -> usize {
        if us < LINEAR {
            return us as usize;
        }
        let oct = 63 - us.leading_zeros() as usize; // floor(log2), >= 8
        let frac = ((us - (1 << oct)) * SUB as u64 >> oct) as usize;
        (LINEAR as usize + (oct - 8) * SUB + frac).min(NBUCKETS - 1)
    }

    fn bucket_value(idx: usize) -> u64 {
        if idx < LINEAR as usize {
            return idx as u64;
        }
        let r = idx - LINEAR as usize;
        let oct = 8 + r / SUB;
        let frac = (r % SUB) as u64;
        (1u64 << oct) + (frac << oct) / SUB as u64
    }

    pub fn record_us(&self, us: u64) {
        self.buckets[Self::index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// p in [0, 100].
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        self.max_us()
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={}us p95={}us p99={}us max={}us",
            self.count(),
            self.mean_us(),
            self.percentile_us(50.0),
            self.percentile_us(95.0),
            self.percentile_us(99.0),
            self.max_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_monotone() {
        let mut last = 0;
        for us in [1u64, 2, 3, 5, 9, 17, 100, 1000, 123_456, 10_000_000] {
            let i = Histogram::index(us);
            assert!(i >= last);
            last = i;
        }
    }

    #[test]
    fn bucket_value_brackets_input() {
        for us in [0u64, 1, 7, 63, 255, 256, 257, 1000, 4095, 1 << 20, 1 << 31] {
            let idx = Histogram::index(us);
            let lo = Histogram::bucket_value(idx);
            assert!(lo <= us, "lo {lo} us {us}");
            // next bucket must be above
            let hi = Histogram::bucket_value(idx + 1);
            assert!(hi > us, "hi {hi} us {us}");
        }
    }

    #[test]
    fn percentiles_reasonable() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_us(i);
        }
        let p50 = h.percentile_us(50.0);
        assert!((450..=560).contains(&p50), "p50={p50}");
        let p99 = h.percentile_us(99.0);
        assert!((900..=1100).contains(&p99), "p99={p99}");
        assert_eq!(h.count(), 1000);
        assert!((h.mean_us() - 500.5).abs() < 1.0);
    }

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }
}
