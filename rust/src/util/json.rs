//! Minimal JSON parser/serializer (no serde in the dependency set).
//!
//! Covers the full JSON grammar we exchange with the python build step
//! (`artifacts/manifest.json`, dataset JSONL, experiment configs) plus
//! pretty/compact serialization for result dumps.  Object key order is
//! preserved (Vec-backed) so emitted reports diff cleanly.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Json {
        if let Json::Obj(fields) = self {
            if let Some(f) = fields.iter_mut().find(|(k, _)| k == key) {
                f.1 = value;
            } else {
                fields.push((key.to_string(), value));
            }
        }
        self
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `a.b.c` path lookup.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    // ---- serialization ----
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(w) = indent {
                out.push('\n');
                for _ in 0..(w * d) {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("bad surrogate pair"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // re-assemble utf-8 multibyte sequences
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let slice = &self.bytes[start..self.pos];
                    match std::str::from_utf8(slice) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return Err(self.err("invalid utf-8")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "c"}, null], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.path("d.e"), Some(&Json::Bool(false)));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"x":[1,2.5,"s"],"y":{"z":true},"empty":[],"eo":{}}"#;
        let v = parse(src).unwrap();
        let c = v.to_string_compact();
        assert_eq!(parse(&c).unwrap(), v);
        let p = v.to_string_pretty();
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ tab\t nl\n unicode \u{1F600} end".into());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v, Json::Str("\u{1F600}".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"\\q\"", "[1] x"] {
            assert!(parse(bad).is_err(), "should reject {:?}", bad);
        }
    }

    #[test]
    fn object_set_get() {
        let mut o = Json::obj();
        o.set("k", Json::Num(1.0)).set("k", Json::Num(2.0)).set("j", Json::Null);
        assert_eq!(o.get("k"), Some(&Json::Num(2.0)));
        assert_eq!(o.as_obj().unwrap().len(), 2);
    }

    #[test]
    fn jsonl_items_parse() {
        let line = r#"{"prompt": "Q mira hue ? A", "choices": ["red","blue","gold","gray"], "answer": 2}"#;
        let v = parse(line).unwrap();
        assert_eq!(v.usize_or("answer", 9), 2);
        assert_eq!(v.get("choices").unwrap().as_arr().unwrap()[1].as_str(),
                   Some("blue"));
    }
}
