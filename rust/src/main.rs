//! `repro` — the FourierCompress CLI: serving coordinator, device
//! client, experiment drivers (tables/figures), analysis dumps, and
//! the multi-client simulator.  See README.md for a tour.

use anyhow::Result;
use fourier_compress::config::{EvalConfig, FromJson, ServeConfig, SimConfig};
use fourier_compress::coordinator::{DeviceClient, EdgeServer};
use fourier_compress::eval::tables::{self, EvalContext};
use fourier_compress::info;
use fourier_compress::net::Channel;
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::sim;
use fourier_compress::util::cli::Args;
use fourier_compress::util::json::Json;
use std::sync::Arc;

const USAGE: &str = "\
repro — FourierCompress reproduction CLI

USAGE: repro <command> [--config FILE] [--set key=value]...

Commands:
  eval       accuracy experiments (--table2 --table3 --fig4 --fig5 or --all)
  analyze    Fig-2 activation analysis (--model NAME --ratio R)
  simulate   Fig-7 multi-client DES (--set compute_units=8 ...)
  serve      run the edge server (--set listen=.. ratio=8 ...)
  client     run a device client (--addr A --prompt P --max-new N --gbps G)
  info       print manifest summary
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let overrides = args.get_all("set");
    match args.subcommand.as_deref() {
        Some("eval") => cmd_eval(&args, &overrides),
        Some("analyze") => cmd_analyze(&args, &overrides),
        Some("simulate") => cmd_simulate(&args, &overrides),
        Some("serve") => cmd_serve(&args, &overrides),
        Some("client") => cmd_client(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_eval(args: &Args, overrides: &[String]) -> Result<()> {
    let cfg = EvalConfig::load(args.get("config"), overrides)?;
    let out_dir = cfg.out.clone();
    let ctx = EvalContext::new(cfg)?;
    let all = args.has("all");
    let datasets = ctx.datasets();

    let t2 = if all || args.has("table2") {
        let t2 = tables::table2(&ctx)?;
        println!("{}", tables::render_table(&t2, &datasets));
        Some(t2)
    } else {
        // reuse a previous table2 run when available
        std::fs::read_to_string(format!("{out_dir}/table2.json"))
            .ok()
            .and_then(|s| fourier_compress::util::json::parse(&s).ok())
    };

    if all || args.has("table3") {
        let t2 = t2.clone().unwrap_or_else(Json::obj);
        let t3 = tables::table3(&ctx, &t2)?;
        println!("{}", tables::render_table(&t3, &datasets));
    }
    if all || args.has("fig4") {
        let model = args.str_or("model", "llamette-s");
        tables::fig4(&ctx, &model, &["pa", "oa", "cq", "ae"])?;
    }
    if all || args.has("fig5") {
        let model = args.str_or("model", "llamette-s");
        tables::fig5(&ctx, &model, &["pa", "oa"])?;
    }
    Ok(())
}

fn cmd_analyze(args: &Args, overrides: &[String]) -> Result<()> {
    let cfg = EvalConfig::load(args.get("config"), overrides)?;
    let out_dir = cfg.out.clone();
    let ctx = EvalContext::new(cfg)?;
    let model = args.str_or("model", "llamette-s");
    let ratio = args.f64_or("ratio", 8.0);
    let j = fourier_compress::eval::analysis::analyze(&ctx, &model, ratio)?;
    std::fs::create_dir_all(&out_dir)?;
    let path = format!("{out_dir}/fig2_{model}.json");
    std::fs::write(&path, j.to_string_pretty())?;
    info!("analyze", "wrote {path}");
    if let Some(s) = j.get("similarity_by_layer").and_then(|v| v.get("oa")) {
        println!("similarity by layer (oa): {}", s.to_string_compact());
    }
    if let Some(e) = j.path("recon_error_by_layer.fc") {
        println!("fc recon err by layer:    {}", e.to_string_compact());
    }
    if let Some(e) = j.path("recon_error_by_layer.topk") {
        println!("topk recon err by layer:  {}", e.to_string_compact());
    }
    Ok(())
}

fn cmd_simulate(args: &Args, overrides: &[String]) -> Result<()> {
    let cfg = SimConfig::load(args.get("config"), overrides)?;
    let j = sim::fig7(&cfg);
    let out = args.str_or("out", "results");
    std::fs::create_dir_all(&out)?;
    let path = format!("{out}/fig7_units{}.json", cfg.compute_units);
    std::fs::write(&path, j.to_string_pretty())?;
    info!("simulate", "wrote {path}");
    println!("{}", j.to_string_pretty());
    Ok(())
}

fn cmd_serve(args: &Args, overrides: &[String]) -> Result<()> {
    let cfg = ServeConfig::load(args.get("config"), overrides)?;
    let store = Arc::new(ArtifactStore::open(cfg.artifacts.clone())?);
    let handle = EdgeServer::start(cfg, store)?;
    println!("serving on {} — ctrl-c to stop", handle.addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7433");
    let artifacts = args.str_or("artifacts", "artifacts");
    let prompt = args.str_or("prompt", "Q mira hue ? A");
    let max_new = args.usize_or("max-new", 8);
    let gbps = args.f64_or("gbps", 0.0);
    let channel = if gbps > 0.0 {
        Channel::gbps(gbps, args.usize_or("latency-us", 100) as u64)
    } else {
        Channel::unlimited()
    };
    let store = ArtifactStore::open(artifacts)?;
    let mut client = DeviceClient::connect(&addr, &store, 1, channel)?;
    let gen = client.generate(&prompt, max_new)?;
    println!("prompt:     {}", gen.prompt);
    println!("completion: {:?}", gen.completion);
    println!("steps:      {}", gen.steps);
    println!("bytes sent: {} (vs {} uncompressed, ratio {:.1}x)",
             client.stats.bytes_sent, client.stats.bytes_uncompressed,
             client.stats.compression_ratio());
    println!("server:     {}", client.server_stats()?);
    client.bye()?;
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let store = ArtifactStore::open(args.str_or("artifacts", "artifacts"))?;
    println!("platform: {}", store.runtime.platform());
    for m in store.model_names() {
        let j = store.model_meta(&m)?;
        println!("model {m}: d={} L={} params={}",
                 j.usize_or("d_model", 0), j.usize_or("n_layers", 0),
                 j.usize_or("n_params", 0));
    }
    println!("datasets: {}", store.dataset_names().join(", "));
    if store.manifest.get("serving").is_some() {
        println!("serving: {}",
                 store.manifest.path("serving.model").and_then(|v| v.as_str())
                     .unwrap_or("?"));
    }
    Ok(())
}
