//! Scale soak for the sharded, event-driven serving core: a thousand
//! concurrent in-proc sessions — recompute, adaptive, and spectral
//! stream clients mixed — multiplexed over the fixed poll pool, with
//! hard assertions on per-session token parity against the recompute
//! reference (which doubles as the zero-cross-session-bleed check:
//! every session must get *its own prompt's* tokens back), on clean
//! shutdown with no leaked worker threads, and on the hung-peer
//! regression: one silent connection must not stall anyone else's
//! step latency even with a single poll worker.
//!
//! Everything is seeded and deterministic: prompt assignment and the
//! client-mode mix derive from the session id, the forged model is
//! byte-stable, and stream clients run with `drift_threshold = 0` so
//! their tokens are bit-identical to the recompute path.

use fourier_compress::codec::rate::RateConfig;
use fourier_compress::codec::stream::StreamConfig;
use fourier_compress::config::ServeConfig;
use fourier_compress::coordinator::protocol::{ErrorCode, Frame};
use fourier_compress::coordinator::{start_service, DeviceClient, FlightKind,
                                    Transport, CLIENT_CAPS};
use fourier_compress::model::tokenizer;
use fourier_compress::testkit::forged_store;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tests in this binary measure process-wide thread counts, so they
/// must not overlap.
static SOAK_LOCK: Mutex<()> = Mutex::new(());

fn serve_config(store_root: &std::path::Path, overrides: &[String]) -> ServeConfig {
    use fourier_compress::config::FromJson;
    let mut args = vec![
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store_root.display()),
    ];
    args.extend_from_slice(overrides);
    ServeConfig::load(None, &args).unwrap()
}

/// Live threads in this process, from procfs (Linux CI).
fn live_threads() -> usize {
    let status = std::fs::read_to_string("/proc/self/status")
        .expect("read /proc/self/status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line in /proc/self/status")
}

/// An adaptive config whose controller genuinely runs every step but
/// deterministically holds the primary point on an in-proc link: the
/// deadline is far above any measurable in-proc send time, so
/// `desired()` always lands on point 0 and tokens stay parity-exact
/// with the recompute reference even under scheduler noise.
fn soak_rate_config() -> RateConfig {
    RateConfig { target_step_s: 5.0, ..RateConfig::default() }
}

const SESSIONS: u64 = 1024;
const DRIVERS: u64 = 32;
const STEPS: usize = 3;
const PROMPTS: [&str; 4] = [
    "Q probe alpha ? A",
    "Q probe bravo ? A",
    "Q mira hue ? A",
    "Q probe delta ? A",
];

fn prompt_of(session: u64) -> usize {
    (session as usize * 7 + 3) % PROMPTS.len()
}

#[test]
fn thousand_concurrent_sessions_keep_token_parity() {
    let _guard = SOAK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let baseline_threads = live_threads();

    let store = Arc::new(forged_store("scale_soak").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[
        "max_batch=8".into(),
        "batch_deadline_us=200".into(),
        "compute_units=2".into(),
        "shards=8".into(),
        "poll_workers=4".into(),
        "idle_deadline_ms=0".into(), // no idle reaping during the soak
    ]);
    let handle = start_service(&cfg, store.clone()).unwrap();

    // recompute references, one per prompt — the parity oracle every
    // concurrent session (whatever its mode) is judged against
    let mut references = Vec::new();
    for (p, prompt) in PROMPTS.iter().enumerate() {
        let mut oracle = DeviceClient::connect_over(
            Box::new(handle.connect_inproc()), &store, 900_001 + p as u64)
            .unwrap();
        let mut context = tokenizer::encode_prompt(prompt);
        let mut tokens = Vec::new();
        for _ in 0..STEPS {
            let (token, _) = oracle.step(&context).unwrap();
            context.push(token);
            tokens.push(token);
        }
        oracle.bye().unwrap();
        references.push(tokens);
    }

    // the soak proper: 32 driver threads × 32 pipelined sessions each
    // — 1024 connections concurrently registered with the poll pool
    let per_driver = SESSIONS / DRIVERS;
    const POISON_SESSION: u64 = 777_777;
    std::thread::scope(|scope| {
        // forced-failure injection: while the full soak is in flight,
        // one rogue connection ships a delta no keyframe ever seeded
        // — the service must reject it with a typed StreamReject and
        // the flight recorder must capture enough to diagnose it
        // (asserted below, after the drivers join)
        {
            let handle = &handle;
            let store = &store;
            scope.spawn(move || {
                let (bucket, ks, kd) = store.manifest
                    .path("serving.buckets")
                    .and_then(|b| b.as_obj())
                    .map(|o| (o[0].0.parse::<u16>().unwrap(),
                              o[0].1.usize_or("ks", 0) as u16,
                              o[0].1.usize_or("kd", 0) as u16))
                    .expect("manifest geometry");
                let (mut tx, mut rx) =
                    (Box::new(handle.connect_inproc()) as Box<dyn Transport>)
                        .split().unwrap();
                tx.send(&Frame::hello(POISON_SESSION, CLIENT_CAPS,
                                      "forge-tiny")).unwrap();
                assert!(matches!(rx.recv().unwrap(),
                                 Frame::HelloAck { .. }));
                tx.send(&Frame::Delta {
                    session: POISON_SESSION, request: 1, seq: 7,
                    keyframe: false, bucket, true_len: 4, ks, kd, point: 0,
                    packed: vec![], updates: vec![(0, 1.0)],
                    coded: vec![],
                }).unwrap();
                match rx.recv().unwrap() {
                    Frame::Error { code, .. } => {
                        assert_eq!(code, ErrorCode::StreamReject,
                                   "poisoned delta must StreamReject");
                    }
                    other => panic!("poisoned delta answered {}",
                                    other.type_id()),
                }
                tx.send(&Frame::Bye).unwrap();
            });
        }
        for d in 0..DRIVERS {
            let handle = &handle;
            let store = &store;
            let references = &references;
            scope.spawn(move || {
                // open every connection up front so all of this
                // driver's sessions are concurrently live...
                let sessions: Vec<u64> =
                    (0..per_driver).map(|i| 1 + d * per_driver + i).collect();
                let mut clients: Vec<(u64, DeviceClient, Vec<i32>)> = sessions
                    .iter()
                    .map(|&sid| {
                        let c = DeviceClient::connect_over(
                            Box::new(handle.connect_inproc()), store, sid)
                            .unwrap_or_else(|e| {
                                panic!("session {sid}: connect: {e:#}")
                            });
                        let ctx = tokenizer::encode_prompt(
                            PROMPTS[prompt_of(sid)]);
                        (sid, c, ctx)
                    })
                    .collect();
                for (sid, client, _) in clients.iter_mut() {
                    match *sid % 3 {
                        1 => assert!(client.enable_adaptive(soak_rate_config()),
                                     "session {sid}: adaptive refused"),
                        2 => assert!(client.enable_stream(StreamConfig {
                                         keyframe_interval: 32,
                                         drift_threshold: 0.0 }),
                                     "session {sid}: stream refused"),
                        _ => {}
                    }
                }
                // ...then interleave the decode steps: split-phase
                // send/recv pipelining for recompute+adaptive
                // sessions, lockstep for stream sessions
                for step in 0..STEPS {
                    let mut inflight: Vec<(usize, u64)> = Vec::new();
                    for (slot, (sid, client, ctx)) in
                        clients.iter_mut().enumerate() {
                        let want =
                            references[prompt_of(*sid)][step];
                        if *sid % 3 == 2 {
                            let (token, _) = client.step(&ctx[..])
                                .unwrap_or_else(|e| panic!(
                                    "session {sid} step {step}: {e:#}"));
                            assert_eq!(token, want,
                                       "session {sid} (stream) step {step} \
                                        diverged from its prompt's reference");
                            ctx.push(token);
                        } else {
                            let req = client.step_send(&ctx[..])
                                .unwrap_or_else(|e| panic!(
                                    "session {sid} step {step}: {e:#}"));
                            inflight.push((slot, req));
                        }
                    }
                    for (slot, req) in inflight {
                        let (sid, client, ctx) = &mut clients[slot];
                        let (token, logprob) = client.step_recv(req)
                            .unwrap_or_else(|e| panic!(
                                "session {sid} step {step} recv: {e:#}"));
                        let want = references[prompt_of(*sid)][step];
                        assert!(logprob <= 0.0);
                        assert_eq!(token, want,
                                   "session {sid} step {step} diverged \
                                    from its prompt's reference");
                        ctx.push(token);
                    }
                }
                for (sid, mut client, _) in clients {
                    client.bye().unwrap_or_else(|e| {
                        panic!("session {sid}: bye: {e:#}")
                    });
                }
            });
        }
    });

    // the injected failure is diagnosable from the flight dump alone:
    // the reject event names the poisoned session, the shard its
    // state lives in, and the offending sequence number
    let dump = handle.dump_flight();
    let reject = dump.iter()
        .find(|e| e.kind == FlightKind::StreamReject
              && e.session == POISON_SESSION)
        .unwrap_or_else(|| panic!(
            "poisoned delta missing from flight dump ({} events)",
            dump.len()));
    assert_eq!(reject.seq, 7, "dump must carry the poisoned sequence");
    assert_eq!(reject.shard as usize,
               handle.service().shard_of(POISON_SESSION),
               "dump must name the session's shard");
    assert_eq!(handle.metrics.stream_rejects.load(Ordering::Relaxed), 1,
               "exactly the injected frame was rejected");

    // the service saw every step from every session, batched them,
    // and opened/closed exactly the connections we made
    let m = &handle.metrics;
    let want_steps = (SESSIONS as usize * STEPS) as u64;
    assert!(m.requests.load(Ordering::Relaxed) >= want_steps,
            "server requests {} < {want_steps}",
            m.requests.load(Ordering::Relaxed));
    assert!(m.tokens.load(Ordering::Relaxed) >= want_steps);
    assert!(m.batches.load(Ordering::Relaxed) >= 1);
    assert!(m.conns_opened.load(Ordering::Relaxed)
            >= SESSIONS + PROMPTS.len() as u64);
    assert_eq!(m.idle_disconnects.load(Ordering::Relaxed), 0,
               "idle reaping was disabled for the soak");

    // every Bye'd connection must retire from the poll queue on its
    // own — before shutdown is ever called
    let drained = Instant::now();
    while handle.conn_count() > 0 {
        assert!(drained.elapsed() < Duration::from_secs(30),
                "{} connections never retired", handle.conn_count());
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(m.conns_opened.load(Ordering::Relaxed),
               m.conns_closed.load(Ordering::Relaxed),
               "open/close accounting diverged");

    // clean shutdown: poll workers, compute workers, and the feed all
    // stop; the process thread count returns to its pre-test baseline
    handle.shutdown();
    let deadline = Instant::now();
    loop {
        let now = live_threads();
        if now <= baseline_threads {
            break;
        }
        assert!(deadline.elapsed() < Duration::from_secs(10),
                "leaked worker threads: {now} live, baseline \
                 {baseline_threads}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn hung_peer_cannot_stall_other_sessions() {
    let _guard = SOAK_LOCK.lock().unwrap_or_else(|e| e.into_inner());

    // ONE poll worker and a short idle deadline: if any per-connection
    // receive could still block (the old 60 s in-proc bound), the
    // silent peer would freeze the only worker and the active client's
    // steps would take tens of seconds
    let store = Arc::new(forged_store("hung_peer").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[
        "compute_units=1".into(),
        "poll_workers=1".into(),
        "idle_deadline_ms=200".into(),
    ]);
    let handle = start_service(&cfg, store.clone()).unwrap();

    // a connection that registers and then says nothing — held open so
    // it is hung, not disconnected
    let silent = handle.connect_inproc();

    let mut client = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 1).unwrap();
    // the recompute regime is stateless, so stepping the same context
    // repeatedly is legal — it keeps this client chatty (and alive)
    // without outgrowing the largest bucket while we wait
    let context = tokenizer::encode_prompt("Q probe alpha ? A");
    let mut worst = Duration::ZERO;
    for _ in 0..6 {
        let t0 = Instant::now();
        client.step(&context).unwrap();
        worst = worst.max(t0.elapsed());
    }
    // generous bound — normal steps are sub-millisecond; the old
    // blocking receive would push this past 60 s
    assert!(worst < Duration::from_secs(5),
            "a silent peer stalled an active session: worst step {worst:?}");

    // the silent connection is reaped by the idle deadline — while the
    // active client keeps talking and must NOT be
    let t0 = Instant::now();
    while handle.metrics.idle_disconnects.load(Ordering::Relaxed) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10),
                "idle deadline never fired for the silent connection");
        client.step(&context).unwrap();
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(handle.metrics.idle_disconnects.load(Ordering::Relaxed), 1,
               "the chatty client was idle-reaped too");
    drop(silent);
    client.bye().unwrap();
    handle.shutdown();
}
