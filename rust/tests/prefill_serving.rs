//! Hermetic chunked-prefill tests: the prompt-phase streaming mode
//! negotiated via `caps::PREFILL`, driven end to end through the live
//! server — chunked vs monolithic token parity over in-proc and TCP
//! transports, dropped-chunk → typed reject → keyframe-chunk-0
//! recovery at the service-handle level, the entropy-coded chunk byte
//! reconciliation, and the mixed-capability downgrade against a
//! legacy (prefill off) server.  All tests hard-assert on every
//! checkout — no python, no XLA.

use fourier_compress::codec::stream::{split_prefill, BlockGeom, PrefillChunk,
                                      PrefillConfig};
use fourier_compress::codec::CodecEngine;
use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::protocol::{caps, ErrorCode, Frame};
use fourier_compress::coordinator::{start_service, DeviceClient, EdgeServer,
                                    Reply, Response, CLIENT_CAPS};
use fourier_compress::model::tokenizer;
use fourier_compress::net::Channel;
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::testkit::forged_longctx_store;
use fourier_compress::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

fn serve_config(store_root: &std::path::Path, overrides: &[String])
    -> ServeConfig {
    let mut args = vec![
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store_root.display()),
    ];
    args.extend_from_slice(overrides);
    ServeConfig::load(None, &args).unwrap()
}

/// A multi-dozen-token prompt that buckets to the long-context
/// store's 128-token bucket (the 15x31 packed plane → 4 chunks at
/// `chunk_rows = 4`).
fn long_prompt() -> String {
    let mut p = "pad ".repeat(24);
    p.push_str("Q mira hue ? A");
    p
}

const STEPS: usize = 8;
const CHUNK_CFG: PrefillConfig =
    PrefillConfig { chunk_rows: 4, drift_threshold: 0.0 };

/// Drive one client for `STEPS` tokens; the first step rides
/// `send_prompt` (chunked when prefill is enabled, monolithic
/// fallback otherwise), the rest the ordinary decode path.
fn drive(client: &mut DeviceClient, prompt: &str) -> Vec<i32> {
    let mut ctx = tokenizer::encode_prompt(prompt);
    let mut tokens = Vec::new();
    for i in 0..STEPS {
        let (t, _) = if i == 0 {
            client.send_prompt(&ctx).unwrap()
        } else {
            client.step(&ctx).unwrap()
        };
        ctx.push(t);
        tokens.push(t);
    }
    tokens
}

/// The serving (bucket, ks, kd) of the long-context store's small
/// bucket from the manifest — the geometry every chunk frame in the
/// handle-level tests must carry.
fn small_bucket_geom(store: &ArtifactStore) -> (u16, u16, u16) {
    store.manifest.path("serving.buckets")
        .and_then(|b| b.as_obj())
        .expect("buckets")
        .iter()
        .map(|(bstr, bj)| (bstr.parse::<u16>().unwrap(),
                           bj.usize_or("ks", 0) as u16,
                           bj.usize_or("kd", 0) as u16))
        .min()
        .expect("at least one bucket")
}

fn chunk_frame(session: u64, request: u64, bucket: u16,
               ks: u16, kd: u16, c: &PrefillChunk) -> Frame {
    Frame::PrefillChunk {
        session, request, bucket, true_len: 40, ks, kd, point: 0,
        index: c.index, last: c.last, keyframe: c.keyframe,
        packed: c.packed.clone(), updates: c.updates.clone(),
        coded: vec![],
    }
}

/// Chunked prefill at zero drift threshold is bit-exact, so the
/// generated tokens must match the monolithic prompt path exactly —
/// over TCP and over the in-proc transport — and both sides must
/// account chunks, prompts, and rejects consistently.
#[test]
fn chunked_prefill_matches_monolithic_tokens_over_tcp_and_inproc() {
    let store = Arc::new(forged_longctx_store("prefill_e2e").expect("forge"));
    let server = EdgeServer::start(serve_config(&store.root, &[]),
                                   store.clone()).unwrap();
    let addr = server.addr.to_string();
    let prompt = long_prompt();

    // baseline: monolithic prompt (prefill never enabled — send_prompt
    // falls back to the ordinary recompute step)
    let mut base = DeviceClient::connect(&addr, &store, 71,
                                         Channel::unlimited()).unwrap();
    assert!(base.server_caps() & caps::PREFILL != 0,
            "server must advertise the prefill capability by default");
    assert!(!base.prefill_enabled());
    let base_tokens = drive(&mut base, &prompt);
    assert_eq!(base.stats.prefill_prompts, 0);
    assert_eq!(base.stats.prefill_chunks, 0);
    base.bye().unwrap();
    assert_eq!(server.metrics.prefill_chunks.load(Ordering::Relaxed), 0,
               "monolithic client must not count prefill chunks");

    // chunked over TCP
    let mut tc = DeviceClient::connect(&addr, &store, 72,
                                       Channel::unlimited()).unwrap();
    assert!(tc.enable_prefill(CHUNK_CFG),
            "handshake must negotiate the prefill capability");
    assert!(tc.prefill_enabled());
    let tokens = drive(&mut tc, &prompt);
    assert_eq!(tokens, base_tokens,
               "zero-threshold chunked prefill must be bit-exact: tokens \
                diverged from the monolithic prompt");
    assert_eq!(tc.stats.prefill_prompts, 1);
    // the 15x31 plane at chunk_rows = 4 is exactly 4 chunks
    assert_eq!(tc.stats.prefill_chunks, 4);
    assert!(tc.stats.prefill_key_chunks >= 1
                && tc.stats.prefill_key_chunks <= tc.stats.prefill_chunks);
    assert_eq!(tc.stats.prefill_resyncs, 0);
    assert!(tc.stats.prefill_bytes > 0
                && tc.stats.prefill_bytes <= tc.stats.bytes_sent);
    tc.bye().unwrap();

    // chunked over the in-proc transport: same tokens again
    let mut ic = DeviceClient::connect_over(
        Box::new(server.connect_inproc()), &store, 73).unwrap();
    assert!(ic.enable_prefill(CHUNK_CFG));
    assert_eq!(drive(&mut ic, &prompt), base_tokens,
               "in-proc chunked prefill diverged");
    ic.bye().unwrap();

    // server-side accounting mirrors the two chunked clients
    let m = &server.metrics;
    assert_eq!(m.prefill_prompts.load(Ordering::Relaxed), 2);
    assert_eq!(m.prefill_chunks.load(Ordering::Relaxed), 8);
    assert_eq!(m.prefill_rejects.load(Ordering::Relaxed), 0);
    assert!(m.prefill_bytes_rx.load(Ordering::Relaxed) > 0);
    server.shutdown();
}

/// A dropped chunk is a hard sequence-gap failure: exactly one typed
/// `StreamReject` naming prefill, the rest of the doomed burst is
/// swallowed silently, and a restart from keyframe chunk 0 completes
/// the prompt and serves a token.
#[test]
fn dropped_chunk_is_a_typed_reject_and_keyframe_restart_recovers() {
    let store =
        Arc::new(forged_longctx_store("prefill_resync").expect("forge"));
    let cfg = serve_config(&store.root, &[]);
    let handle = start_service(&cfg, store.clone()).unwrap();
    let service = handle.service();
    let (bucket, ks, kd) = small_bucket_geom(&store);

    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let mut conn = service.open_conn(reply_tx, "prefill-resync".into());
    assert!(matches!(
        service.handle(&mut conn,
                       Frame::hello(7, CLIENT_CAPS, "forge-longctx")),
        Response::Reply(Frame::HelloAck { .. })));

    // a valid chunk sequence for the real serving geometry (the forged
    // model's d_model is 32 — the tiny-spec hidden size)
    let geom = BlockGeom { rows: bucket as usize, cols: 32,
                           ks: ks as usize, kd: kd as usize };
    let mut rng = Rng::new(0xBEEF);
    let plane: Vec<f32> =
        (0..geom.ks * geom.kd).map(|_| rng.normal() as f32).collect();
    let mut eng = CodecEngine::new();
    let (mut chunks, mut state) = (Vec::new(), Vec::new());
    split_prefill(&mut eng, geom, &plane, CHUNK_CFG, &mut chunks, &mut state)
        .unwrap();
    assert_eq!(chunks.len(), 4, "the 15x31 plane must split into 4 chunks");

    // chunk 0 lands, chunk 1 is lost, chunk 2 → one typed reject
    assert!(matches!(
        service.handle(&mut conn, chunk_frame(7, 1, bucket, ks, kd,
                                              &chunks[0])),
        Response::None));
    match service.handle(&mut conn, chunk_frame(7, 1, bucket, ks, kd,
                                                &chunks[2])) {
        Response::Reply(Frame::Error { code: ErrorCode::StreamReject, msg }) =>
            assert!(msg.contains("prefill"), "unexpected reject: {msg}"),
        _ => panic!("a sequence gap must be a typed StreamReject"),
    }
    // the rest of the doomed burst is swallowed silently (no reject
    // storm: one Error per resend attempt)
    assert!(matches!(
        service.handle(&mut conn, chunk_frame(7, 1, bucket, ks, kd,
                                              &chunks[3])),
        Response::None));
    assert_eq!(handle.metrics.prefill_rejects.load(Ordering::Relaxed), 1);
    assert_eq!(handle.metrics.prefill_prompts.load(Ordering::Relaxed), 0);

    // restart from keyframe chunk 0: the full sequence completes the
    // plane and the batcher serves a token
    for c in &chunks {
        assert!(matches!(
            service.handle(&mut conn, chunk_frame(7, 2, bucket, ks, kd, c)),
            Response::None));
    }
    let reply = reply_rx.recv_timeout(Duration::from_secs(30))
        .expect("no token after the recovered prefill");
    assert!(matches!(reply.frame, Frame::Token { .. }),
            "recovered prefill must serve a token");
    assert_eq!(handle.metrics.prefill_prompts.load(Ordering::Relaxed), 1);
    assert_eq!(handle.metrics.prefill_rejects.load(Ordering::Relaxed), 1);

    service.close_conn(&conn);
    drop(conn);
    while reply_rx.try_recv().is_ok() {}
    handle.shutdown();
}

/// Entropy-coded prefill chunks are lossless and the byte accounting
/// reconciles exactly: tokens identical to the raw chunked run, never
/// more bytes on the wire, and `bytes_sent + saved == raw bytes`.
#[test]
fn entropy_coded_prefill_is_lossless_and_reconciles_bytes() {
    let store =
        Arc::new(forged_longctx_store("prefill_entropy").expect("forge"));
    let server = EdgeServer::start(serve_config(&store.root, &[]),
                                   store.clone()).unwrap();
    let addr = server.addr.to_string();
    let prompt = long_prompt();

    // raw chunked baseline
    let mut raw = DeviceClient::connect(&addr, &store, 81,
                                        Channel::unlimited()).unwrap();
    assert!(raw.enable_prefill(CHUNK_CFG));
    let raw_tokens = drive(&mut raw, &prompt);
    let raw_bytes = raw.stats.bytes_sent;
    assert_eq!(raw.stats.entropy_frames + raw.stats.entropy_fallbacks, 0);
    raw.bye().unwrap();

    // entropy-coded chunked run: same prompt, same steps
    let mut ec = DeviceClient::connect(&addr, &store, 82,
                                       Channel::unlimited()).unwrap();
    assert!(ec.enable_prefill(CHUNK_CFG));
    assert!(ec.enable_entropy());
    let tokens = drive(&mut ec, &prompt);
    assert_eq!(tokens, raw_tokens,
               "entropy coding is lossless: chunked tokens must match");
    assert_eq!(ec.stats.prefill_prompts, 1);
    assert_eq!(ec.stats.prefill_chunks, 4);
    assert!(ec.stats.bytes_sent <= raw_bytes,
            "entropy {} B vs raw {} B", ec.stats.bytes_sent, raw_bytes);
    // try-and-compare: every frame (4 chunks + 7 decode steps) was
    // either coded or an explicit raw fallback
    assert_eq!(ec.stats.entropy_frames + ec.stats.entropy_fallbacks,
               (4 + STEPS - 1) as u64);
    let saved = ec.stats.pre_coding_bytes - ec.stats.post_coding_bytes;
    assert_eq!(ec.stats.bytes_sent + saved, raw_bytes,
               "prefill byte accounting does not reconcile");
    assert_eq!(server.metrics.entropy_frames.load(Ordering::Relaxed),
               ec.stats.entropy_frames);
    ec.bye().unwrap();
    server.shutdown();
}

/// Mixed-capability handshake: a PREFILL-capable client against a
/// legacy server (prefill off) downgrades cleanly — `enable_prefill`
/// refuses, `send_prompt` rides the monolithic path, and the wire
/// traffic is byte-identical to a client that never asked for
/// prefill, with identical tokens.
#[test]
fn prefill_client_downgrades_byte_identical_on_legacy_server() {
    let store =
        Arc::new(forged_longctx_store("prefill_legacy").expect("forge"));
    let legacy = EdgeServer::start(
        serve_config(&store.root, &["prefill=false".into()]),
        store.clone()).unwrap();
    let addr = legacy.addr.to_string();
    let prompt = long_prompt();

    // a client that never mentions prefill: the legacy byte stream
    let mut lc = DeviceClient::connect(&addr, &store, 91,
                                       Channel::unlimited()).unwrap();
    assert_eq!(lc.server_caps() & caps::PREFILL, 0);
    let legacy_tokens = drive(&mut lc, &prompt);
    let legacy_bytes = lc.stats.bytes_sent;
    lc.bye().unwrap();

    // a capable client that asks and is refused: identical traffic
    let mut mc = DeviceClient::connect(&addr, &store, 92,
                                       Channel::unlimited()).unwrap();
    assert!(!mc.enable_prefill(CHUNK_CFG),
            "enable_prefill must refuse without the negotiated capability");
    assert!(!mc.prefill_enabled());
    let tokens = drive(&mut mc, &prompt);
    assert_eq!(tokens, legacy_tokens);
    assert_eq!(mc.stats.bytes_sent, legacy_bytes,
               "un-negotiated prefill must leave the wire byte-identical");
    assert_eq!(mc.stats.prefill_prompts, 0);
    assert_eq!(mc.stats.prefill_chunks, 0);
    mc.bye().unwrap();
    assert_eq!(legacy.metrics.prefill_chunks.load(Ordering::Relaxed), 0);
    legacy.shutdown();
}
