//! The hermetic serving soak: N device clients × M generations driven
//! through the real TCP server, dynamic batcher, session manager, and
//! per-connection CodecEngines — all against `testkit`-forged
//! artifacts executed by the pure-Rust reference interpreter.  No
//! `make artifacts`, no XLA: these tests hard-assert on every
//! checkout and are the executable harness future scaling PRs (async
//! server, sharding, batching policies) build on.

use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::{DeviceClient, EdgeServer};
use fourier_compress::model::tokenizer;
use fourier_compress::net::Channel;
use fourier_compress::testkit::{forge_tree, forged_store, ForgeSpec};
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn serve_config(store_root: &std::path::Path, overrides: &[String]) -> ServeConfig {
    let mut args = vec![
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store_root.display()),
    ];
    args.extend_from_slice(overrides);
    ServeConfig::load(None, &args).unwrap()
}

#[test]
fn multi_client_soak_through_tcp_batcher_codec() {
    const CLIENTS: u64 = 4;
    const GENS: usize = 2;

    let store = Arc::new(forged_store("soak").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[
        "max_batch=4".into(),
        "batch_deadline_us=300".into(),
        "compute_units=2".into(),
    ]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();

    let mut handles = Vec::new();
    for cid in 0..CLIENTS {
        let addr = addr.clone();
        let store = store.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = DeviceClient::connect(
                &addr, &store, cid + 1, Channel::unlimited()).unwrap();
            let mut steps = 0usize;
            for g in 0..GENS {
                let prompt = format!("Q probe {cid} {g} ? A");
                let gen = client.generate(&prompt, 4).unwrap();
                assert!(gen.steps >= 1, "client {cid} gen {g}: no tokens");
                steps += gen.steps;
            }
            // per-session engine + conjugate packing must beat raw
            assert!(client.stats.compression_ratio() > 4.0,
                    "client {cid}: ratio {}", client.stats.compression_ratio());
            assert_eq!(client.stats.requests as usize, steps);
            let stats = client.server_stats().unwrap();
            assert!(stats.contains("\"requests\""), "stats json: {stats}");
            client.bye().unwrap();
            steps
        }));
    }
    let total_steps: usize =
        handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total_steps >= (CLIENTS as usize) * GENS);

    let m = &server.metrics;
    assert!(m.requests.load(Ordering::Relaxed) >= total_steps as u64,
            "server saw fewer requests than clients sent");
    assert!(m.tokens.load(Ordering::Relaxed) >= total_steps as u64);
    assert!(m.batches.load(Ordering::Relaxed) >= 1);
    assert!(m.bytes_rx.load(Ordering::Relaxed) > 0);
    assert!(m.bytes_tx.load(Ordering::Relaxed) > 0);
    server.shutdown();
}

#[test]
fn generation_is_deterministic_across_sessions_and_transports() {
    // recompute-regime serving is pure: the same prompt must produce
    // the same tokens regardless of session id, batch composition —
    // or transport medium
    let store = Arc::new(forged_store("determinism").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &["max_batch=2".into()]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();

    let mut first: Option<Vec<i32>> = None;
    for session in [11u64, 12, 13] {
        let mut client =
            DeviceClient::connect(&addr, &store, session, Channel::unlimited())
                .unwrap();
        let g = client.generate("Q mira hue ? A", 4).unwrap();
        client.bye().unwrap();
        if let Some(want) = &first {
            assert_eq!(&g.tokens, want, "session {session} diverged");
        } else {
            first = Some(g.tokens);
        }
    }

    // the same generation, socket-free: an in-proc transport into the
    // same running service must produce byte-identical token output
    // to its TCP twins
    let mut inproc = DeviceClient::connect_over(
        Box::new(server.connect_inproc()), &store, 14).unwrap();
    let g = inproc.generate("Q mira hue ? A", 4).unwrap();
    assert_eq!(Some(g.tokens), first, "in-proc transport diverged from tcp");
    inproc.bye().unwrap();
    server.shutdown();
}

#[test]
fn context_growth_promotes_to_larger_bucket() {
    // a growing prompt must cross the 16-token bucket into the 32
    // bucket mid-generation and keep receiving tokens
    let store = Arc::new(forged_store("bucket_promo").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();

    let mut client =
        DeviceClient::connect(&addr, &store, 7, Channel::unlimited()).unwrap();
    let mut context = tokenizer::encode_prompt("Q mira hue ? A");
    assert!(context.len() < 16);
    let mut crossed = false;
    for _ in 0..6 {
        let (token, logprob) = client.step(&context).unwrap();
        assert!(logprob <= 0.0, "logprob {logprob} not a log-probability");
        context.push(token);
        if context.len() > 16 {
            crossed = true;
        }
    }
    assert!(crossed, "context never crossed the 16-token bucket");
    client.bye().unwrap();
    server.shutdown();
}

#[test]
fn forge_is_deterministic() {
    // the forge's determinism contract: same spec → byte-identical
    // tree (weights, goldens, manifest) — no wall clock, no env
    let ra = fourier_compress::testkit::forge::forge_root("det_a");
    let rb = fourier_compress::testkit::forge::forge_root("det_b");
    let _ = std::fs::remove_dir_all(&ra);
    let _ = std::fs::remove_dir_all(&rb);
    let specs = [ForgeSpec::tiny(), ForgeSpec::tiny_gqa()];
    forge_tree(&ra, &specs, "forge-tiny").unwrap();
    forge_tree(&rb, &specs, "forge-tiny").unwrap();
    for rel in ["manifest.json",
                "weights/forge-tiny.fcw", "weights/forge-gqa.fcw",
                "golden/forge-tiny.golden.fcw", "golden/forge-gqa.golden.fcw"] {
        let a = std::fs::read(ra.join(rel)).unwrap();
        let b = std::fs::read(rb.join(rel)).unwrap();
        assert_eq!(a, b, "{rel} differs between identical forges");
    }
}

#[test]
fn interp_executables_are_selected_without_hlo_files() {
    // the store must serve interpreter-backed executables for every
    // artifact the serving path needs, from a tree with no hlo/ dir
    let store = forged_store("interp_select").expect("forge artifacts");
    assert!(!store.root.join("hlo").exists());
    let serving = store.manifest.get("serving").unwrap();
    let buckets = serving.get("buckets").and_then(|b| b.as_obj()).unwrap();
    let mut loaded = 0;
    for (_, bj) in buckets {
        let cpath = bj.path("client.path").and_then(|v| v.as_str()).unwrap();
        assert!(store.get(cpath).unwrap().is_interpreted());
        loaded += 1;
        for (_, sj) in bj.get("server").and_then(|s| s.as_obj()).unwrap() {
            let spath = sj.get("path").and_then(|v| v.as_str()).unwrap();
            assert!(store.get(spath).unwrap().is_interpreted());
            loaded += 1;
        }
    }
    assert!(loaded >= 4, "expected client+server artifacts per bucket");
    assert_eq!(store.cached_count(), loaded);
    // an artifact with no interp spec still reports the stub error
    let err = store.get("missing_artifact.hlo.txt").unwrap_err();
    assert!(format!("{err:#}").contains("xla runtime unavailable"),
            "unexpected error: {err:#}");
}
