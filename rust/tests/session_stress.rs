//! Concurrency stress for the sharded session table: hammer
//! hello/readmit/touch/bind_owner/release_owner/evict from many
//! threads across shard boundaries and assert the TTL/LRU and
//! ownership-nonce invariants hold under contention — in particular
//! that a session can never be owned by two live connections at once
//! and never resurrects under a foreign connection's nonce.
//!
//! Everything here is deterministic modulo thread interleaving: each
//! thread drives a seeded `Rng` over a shared session-id pool sized
//! so cross-thread (and cross-shard) collisions are constant.

use fourier_compress::coordinator::ShardedSessions;
use fourier_compress::util::rng::Rng;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

const THREADS: u64 = 16;

#[test]
fn sixteen_threads_hammer_every_op_across_shards() {
    let s = Arc::new(ShardedSessions::new(Duration::from_secs(60),
                                          10_000, 8));
    assert_eq!(s.shard_count(), 8);
    // small id pool → constant cross-thread collisions on every shard
    let ids: Vec<u64> = (0..96).map(|i| i * 37 + 5).collect();
    // every pool id must be reachable on some shard, and the pool must
    // span more than one shard or the test exercises nothing
    let touched: std::collections::HashSet<usize> =
        ids.iter().map(|&id| s.shard_of(id)).collect();
    assert!(touched.len() > 1, "id pool landed on a single shard");

    let mut handles = Vec::new();
    for t in 0..THREADS {
        let s = Arc::clone(&s);
        let ids = ids.clone();
        handles.push(std::thread::spawn(move || {
            let conn = t + 1; // this thread's ownership nonce (nonzero)
            let mut rng = Rng::new(0x5E55_0000 + t);
            for _ in 0..2500 {
                let id = *rng.choice(&ids);
                match rng.below(8) {
                    0 => {
                        // the service's Hello gate, atomic in-shard:
                        // a successful bind means nobody else owns it
                        s.with(id, |m| {
                            if !m.owned_by_other(id, conn)
                                && m.hello(id, "stress", 0) {
                                assert!(m.bind_owner(id, conn),
                                        "bind failed after the ownership \
                                         gate passed under the shard lock");
                                assert!(!m.owned_by_other(id, conn));
                            }
                        });
                    }
                    1 => {
                        let _ = s.readmit(id);
                    }
                    2 => {
                        let _ = s.touch(id, 64);
                    }
                    3 => {
                        // blind bind must refuse when foreign-owned
                        s.with(id, |m| {
                            let foreign = m.owned_by_other(id, conn);
                            let bound = m.bind_owner(id, conn);
                            assert!(!(foreign && bound),
                                    "session {id} double-owned");
                        });
                    }
                    4 => s.release_owner(id, conn),
                    5 => {
                        let _ = s.note_point(id, rng.below(3) as u8);
                    }
                    6 => {
                        // eviction may race other threads' binds: all
                        // it must guarantee is it never panics and the
                        // session is re-admittable afterwards
                        s.remove(id);
                        assert!(s.readmit(id), "readmit after remove");
                    }
                    _ => s.evict_expired(),
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("stress thread panicked");
    }

    // table is consistent after the storm: shard sums agree, every id
    // still routes to its stable shard, and every pool id is live or
    // re-admittable
    let lens = s.shard_lens();
    assert_eq!(lens.iter().sum::<usize>(), s.len());
    for &id in &ids {
        assert_eq!(s.shard_of(id), s.shard_of(id));
        assert!(s.readmit(id), "id {id} not admittable after stress");
    }
    assert!(s.len() <= 10_000);
}

#[test]
fn ownership_is_exclusive_under_concurrent_takeover_attempts() {
    // N threads race the full service Hello gate (ownership check →
    // hello → bind, atomic per shard) on a handful of sessions; a
    // shared ledger — updated under the same shard lock — proves at
    // most one live connection ever owns a session
    let s = Arc::new(ShardedSessions::new(Duration::from_secs(60), 256, 4));
    let ledger: Arc<Mutex<HashMap<u64, u64>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let ids: Vec<u64> = (0..8).map(|i| 1000 + i * 13).collect();

    let mut handles = Vec::new();
    for t in 0..THREADS {
        let s = Arc::clone(&s);
        let ledger = Arc::clone(&ledger);
        let ids = ids.clone();
        handles.push(std::thread::spawn(move || {
            let conn = 100 + t;
            let mut rng = Rng::new(0x0117_0000 + t);
            let mut owned: Vec<u64> = Vec::new();
            for _ in 0..800 {
                let id = *rng.choice(&ids);
                if rng.below(2) == 0 && !owned.contains(&id) {
                    // takeover attempt; ledger update stays inside the
                    // shard lock so it is exact, not approximate
                    let won = s.with(id, |m| {
                        if m.owned_by_other(id, conn) {
                            return false;
                        }
                        if !m.hello(id, "race", 0) {
                            return false;
                        }
                        assert!(m.bind_owner(id, conn));
                        let prev = ledger.lock().unwrap().insert(id, conn);
                        assert!(prev.is_none() || prev == Some(conn),
                                "session {id}: conn {conn} won the gate \
                                 while conn {} still owned it",
                                prev.unwrap());
                        true
                    });
                    if won {
                        owned.push(id);
                    }
                } else if let Some(pos) =
                    owned.iter().position(|&o| o == id) {
                    owned.swap_remove(pos);
                    s.with(id, |m| {
                        m.release_owner(id, conn);
                        let prev = ledger.lock().unwrap().remove(&id);
                        assert_eq!(prev, Some(conn),
                                   "session {id}: release by non-owner");
                    });
                }
            }
            // teardown, like close_conn on every live binding
            for id in owned {
                s.with(id, |m| {
                    m.release_owner(id, conn);
                    ledger.lock().unwrap().remove(&id);
                });
            }
        }));
    }
    for h in handles {
        h.join().expect("takeover thread panicked");
    }
    assert!(ledger.lock().unwrap().is_empty(),
            "bindings leaked past connection teardown");
    // with every owner released, any connection can now claim any id
    for &id in &ids {
        assert!(!s.owned_by_other(id, 9999));
    }
}

#[test]
fn evicted_session_never_resurrects_on_a_foreign_connection() {
    let s = ShardedSessions::new(Duration::from_millis(20), 64, 4);
    // conn 1 owns session 42
    assert!(s.hello(42, "m", 0));
    assert!(s.bind_owner(42, 1));
    // a foreign connection can neither claim nor touch it to life
    assert!(s.owned_by_other(42, 2));
    s.with(42, |m| assert!(!m.bind_owner(42, 2)));
    // TTL passes; eviction drops the session AND its binding
    std::thread::sleep(Duration::from_millis(40));
    s.evict_expired();
    assert_eq!(s.len(), 0);
    // the foreign connection's old knowledge of the id is now useless
    // in both directions: no phantom ownership survives...
    assert!(!s.owned_by_other(42, 2));
    // ...and the id is claimable fresh — but only through admission,
    // never via a blind bind of a non-existent session
    s.with(42, |m| assert!(!m.bind_owner(42, 2),
                           "bind resurrected an evicted session"));
    assert_eq!(s.len(), 0, "bind_owner must not create sessions");
    assert!(s.hello(42, "m", 0));
    assert!(s.bind_owner(42, 2));
    assert!(s.owned_by_other(42, 1), "old owner nonce kept rights");
}

#[test]
fn per_shard_lru_budget_holds_under_parallel_admission() {
    // whole-table budget 32 over 4 shards = 8 per shard; admission
    // pressure is enforced shard-locally even under parallel hellos
    let s = Arc::new(ShardedSessions::new(Duration::from_millis(25), 32, 4));
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let s = Arc::clone(&s);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xB0D6 + t);
            for _ in 0..500 {
                let id = rng.below(4096) as u64;
                let _ = s.hello(id, "lru", 0);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    for (i, len) in s.shard_lens().into_iter().enumerate() {
        assert!(len <= 8, "shard {i} holds {len} > its budget of 8");
    }
    // all fresh-TTL: the table refuses further admission on a full
    // shard rather than evicting live sessions... so total <= 32
    assert!(s.len() <= 32);
    // once the TTL lapses the whole table drains
    std::thread::sleep(Duration::from_millis(50));
    s.evict_expired();
    assert!(s.is_empty());
}
