//! Hermetic spectral-delta-stream tests: the full stream protocol
//! (keyframes, sparse deltas, sequence-gap rejection, TTL resync)
//! both at codec level over a 128-step decode and end-to-end through
//! the live TCP server against testkit-forged artifacts.  All tests
//! hard-assert on every checkout — no python, no XLA.

use fourier_compress::codec::fourier::FourierCodec;
use fourier_compress::codec::stream::{fc_payload, BlockGeom, StreamConfig,
                                      StreamDecoder, StreamEncoder, StreamStep};
use fourier_compress::codec::{rel_error, Codec, CodecEngine};
use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::protocol::Frame;
use fourier_compress::coordinator::{DeviceClient, EdgeServer};
use fourier_compress::model::tokenizer;
use fourier_compress::net::Channel;
use fourier_compress::testkit::forged_store;
use fourier_compress::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn serve_config(store_root: &std::path::Path, overrides: &[String])
    -> ServeConfig {
    let mut args = vec![
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store_root.display()),
    ];
    args.extend_from_slice(overrides);
    ServeConfig::load(None, &args).unwrap()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The ISSUE's acceptance pin: a 128-step decode in the delta regime
/// transmits >= 5x fewer cumulative wire bytes than the recompute
/// regime while every step's reconstruction stays within the drift
/// threshold of the keyframe-exact reconstruction.
#[test]
fn stream_128_steps_beats_recompute_5x_within_drift() {
    let geom = BlockGeom { rows: 64, cols: 128, ks: 33, kd: 15 };
    let n = geom.ks * geom.kd;
    let threshold = 0.05;
    let mut rng = Rng::new(0x57AE);
    let mut truth: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut enc = StreamEncoder::new(StreamConfig {
        keyframe_interval: 16,
        drift_threshold: threshold,
    });
    let mut dec = StreamDecoder::new();
    let mut eng = CodecEngine::new();
    let codec = FourierCodec::default();
    let mut step_out = StreamStep::default();

    let (mut recompute_bytes, mut stream_bytes) = (0u64, 0u64);
    let (mut keys, mut deltas) = (0u64, 0u64);
    for step in 0..128u64 {
        if step > 0 {
            // decode-step evolution: the appended token's in-band
            // contribution moves a handful of spectral coefficients
            for _ in 0..3 {
                let i = rng.below(n);
                truth[i] += 0.35 * rng.normal() as f32;
            }
        }
        // recompute regime: the full FC payload every step
        let recompute = Frame::Activation {
            session: 1, request: step + 1, bucket: geom.rows as u16,
            true_len: geom.rows as u16, ks: geom.ks as u16,
            kd: geom.kd as u16, point: 0, packed: truth.clone(),
            coded: vec![],
        };
        recompute_bytes += recompute.encode().len() as u64;

        // stream regime
        enc.encode_into(&mut eng, geom, &truth, &mut step_out).unwrap();
        let frame = Frame::Delta {
            session: 1, request: step + 1, seq: step_out.seq,
            keyframe: step_out.keyframe, bucket: geom.rows as u16,
            true_len: geom.rows as u16, ks: geom.ks as u16,
            kd: geom.kd as u16, point: 0, packed: step_out.packed.clone(),
            updates: step_out.updates.clone(),
            coded: vec![],
        };
        stream_bytes += frame.encode().len() as u64;
        if step_out.keyframe {
            keys += 1;
            dec.apply_key(step_out.seq, geom, &step_out.packed).unwrap();
        } else {
            deltas += 1;
            dec.apply_delta(step_out.seq, geom, &step_out.updates).unwrap();
        }

        // per-step drift bound: reconstruction from the decoder state
        // vs reconstruction from the true block
        let want = codec.decompress(&fc_payload(geom, &truth)).unwrap();
        let got = codec.decompress(&fc_payload(geom, dec.block())).unwrap();
        let err = rel_error(&want, &got);
        assert!(err <= threshold * 1.02 + 1e-6, "step {step}: drift {err}");
    }
    assert!(keys >= 8, "keyframe cadence broke: {keys} keyframes");
    assert!(deltas >= 100, "delta regime never engaged: {deltas} deltas");
    let ratio = recompute_bytes as f64 / stream_bytes as f64;
    assert!(ratio >= 5.0,
            "stream saved only {ratio:.1}x ({recompute_bytes} vs \
             {stream_bytes} B over 128 steps)");
}

/// Drop a delta frame on the floor: the decoder must reject the next
/// frame (sequence gap), stay desynced through further deltas, and a
/// forced keyframe must recover byte-identical state.
#[test]
fn dropped_delta_rejects_then_keyframe_recovers_bitexact() {
    let geom = BlockGeom { rows: 16, cols: 32, ks: 5, kd: 7 };
    let n = geom.ks * geom.kd;
    let mut rng = Rng::new(0xD20B);
    let mut truth: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut enc = StreamEncoder::new(StreamConfig {
        keyframe_interval: 1024,
        drift_threshold: 0.0,
    });
    let mut dec = StreamDecoder::new();
    let mut eng = CodecEngine::new();
    let mut out = StreamStep::default();

    let mut mutate = |truth: &mut Vec<f32>, rng: &mut Rng| {
        for _ in 0..2 {
            let i = rng.below(n);
            truth[i] = rng.normal() as f32;
        }
    };

    // healthy stream: key + applied deltas track the truth bit-exactly
    for step in 0..5u32 {
        if step > 0 {
            mutate(&mut truth, &mut rng);
        }
        enc.encode_into(&mut eng, geom, &truth, &mut out).unwrap();
        if out.keyframe {
            dec.apply_key(out.seq, geom, &out.packed).unwrap();
        } else {
            dec.apply_delta(out.seq, geom, &out.updates).unwrap();
        }
        assert_eq!(bits(dec.block()), bits(&truth), "step {step}");
    }

    // the next delta is encoded but DROPPED on the wire
    mutate(&mut truth, &mut rng);
    enc.encode_into(&mut eng, geom, &truth, &mut out).unwrap();
    assert!(!out.keyframe);

    // the following delta arrives: sequence gap -> hard fail + desync
    mutate(&mut truth, &mut rng);
    enc.encode_into(&mut eng, geom, &truth, &mut out).unwrap();
    assert!(dec.apply_delta(out.seq, geom, &out.updates).is_err());
    assert!(!dec.is_synced());

    // every further delta is refused until a keyframe
    mutate(&mut truth, &mut rng);
    enc.encode_into(&mut eng, geom, &truth, &mut out).unwrap();
    assert!(dec.apply_delta(out.seq, geom, &out.updates).is_err());

    // client-side recovery: force a keyframe -> byte-identical state
    enc.force_keyframe();
    mutate(&mut truth, &mut rng);
    enc.encode_into(&mut eng, geom, &truth, &mut out).unwrap();
    assert!(out.keyframe);
    dec.apply_key(out.seq, geom, &out.packed).unwrap();
    assert_eq!(bits(dec.block()), bits(&truth));

    // and the stream continues cleanly
    mutate(&mut truth, &mut rng);
    enc.encode_into(&mut eng, geom, &truth, &mut out).unwrap();
    assert!(!out.keyframe);
    dec.apply_delta(out.seq, geom, &out.updates).unwrap();
    assert_eq!(bits(dec.block()), bits(&truth));
}

/// Stream mode with a zero drift threshold is lossless end to end:
/// driven through the live TCP server, batcher, and session manager,
/// it must produce exactly the recompute regime's tokens while never
/// sending materially more bytes.
#[test]
fn stream_mode_generates_identical_tokens_lossless() {
    let store = Arc::new(forged_store("stream_lossless").expect("forge"));
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();
    const STEPS: usize = 8;

    // reference: recompute regime (plain Activation frames)
    let mut base = DeviceClient::connect(&addr, &store, 21,
                                         Channel::unlimited()).unwrap();
    let mut ctx = tokenizer::encode_prompt("Q mira hue ? A");
    let mut base_tokens = Vec::new();
    for _ in 0..STEPS {
        let (t, _) = base.step(&ctx).unwrap();
        ctx.push(t);
        base_tokens.push(t);
    }
    let base_bytes = base.stats.bytes_sent;
    base.bye().unwrap();

    // stream mode, zero threshold: deltas replace every changed
    // coefficient exactly, so reconstruction — and therefore every
    // token — matches the recompute regime
    let mut sc = DeviceClient::connect(&addr, &store, 22,
                                       Channel::unlimited()).unwrap();
    assert!(sc.enable_stream(StreamConfig {
        keyframe_interval: 64,
        drift_threshold: 0.0,
    }), "handshake must negotiate the stream capability");
    assert!(sc.stream_enabled());
    let mut ctx = tokenizer::encode_prompt("Q mira hue ? A");
    let mut tokens = Vec::new();
    for _ in 0..STEPS {
        let (t, _) = sc.step(&ctx).unwrap();
        ctx.push(t);
        tokens.push(t);
    }
    assert_eq!(tokens, base_tokens, "stream mode diverged from recompute");
    assert_eq!((sc.stats.key_frames + sc.stats.delta_frames) as usize, STEPS);
    assert!(sc.stats.key_frames >= 1, "first frame must be a keyframe");
    assert_eq!(sc.stats.resyncs, 0);
    // the growing context crosses the 16-token bucket mid-run: the
    // geometry change must have forced a fresh keyframe
    assert!(ctx.len() > 16, "context never crossed the 16-token bucket");
    assert!(sc.stats.key_frames >= 2, "bucket promotion must resync");
    // a stream frame is never materially larger than its Activation
    // twin (a fallback keyframe costs the 5 extra header bytes)
    assert!(sc.stats.bytes_sent <= base_bytes + (STEPS * 16) as u64,
            "stream {} B vs recompute {} B", sc.stats.bytes_sent, base_bytes);

    // server saw the split
    let m = &server.metrics;
    assert!(m.key_frames.load(Ordering::Relaxed) >= 2);
    assert_eq!(m.key_frames.load(Ordering::Relaxed)
                   + m.delta_frames.load(Ordering::Relaxed),
               STEPS as u64);
    assert!(m.key_bytes_rx.load(Ordering::Relaxed) > 0);
    assert_eq!(m.stream_rejects.load(Ordering::Relaxed), 0);
    sc.bye().unwrap();
    server.shutdown();
}

/// TTL-evict the server-side stream state mid-generation: the next
/// delta must be rejected and the client must recover transparently
/// with exactly one keyframe resync.
#[test]
fn ttl_eviction_mid_stream_recovers_via_keyframe_resync() {
    let store = Arc::new(forged_store("stream_ttl").expect("forge"));
    let cfg = serve_config(&store.root, &["session_ttl_s=1".into()]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();

    let mut sc = DeviceClient::connect(&addr, &store, 31,
                                       Channel::unlimited()).unwrap();
    // a high threshold keeps every post-keyframe step in the delta
    // regime regardless of how much the activation moves
    assert!(sc.enable_stream(StreamConfig {
        keyframe_interval: 1024,
        drift_threshold: 0.9,
    }));
    // short prompt: all four steps stay inside the 16-token bucket,
    // so no geometry-change keyframes muddy the resync accounting
    // (BOS + 9 bytes = 10 tokens, +4 generated = 14 <= 16)
    let mut ctx = tokenizer::encode_prompt("Q rok ? A");
    let (t1, _) = sc.step(&ctx).unwrap(); // keyframe
    ctx.push(t1);
    let (t2, _) = sc.step(&ctx).unwrap(); // delta
    ctx.push(t2);
    assert_eq!(sc.stats.key_frames, 1);
    assert_eq!(sc.stats.delta_frames, 1);
    assert_eq!(sc.stats.resyncs, 0);

    std::thread::sleep(std::time::Duration::from_millis(1400));
    // the server evicted the session: the next delta is rejected and
    // the client transparently resends as a keyframe
    let (_t3, _) = sc.step(&ctx).unwrap();
    assert_eq!(sc.stats.resyncs, 1, "expected exactly one resync");
    assert_eq!(sc.stats.key_frames, 2);
    assert_eq!(server.metrics.stream_rejects.load(Ordering::Relaxed), 1);

    // the resynced stream keeps working without further keyframes
    ctx.push(_t3);
    let (_t4, _) = sc.step(&ctx).unwrap();
    assert_eq!(sc.stats.resyncs, 1);
    assert_eq!(sc.stats.key_frames, 2);
    sc.bye().unwrap();
    server.shutdown();
}
