//! End-to-end coordinator test: real TCP server + device client with
//! the fused (codec-in-graph) artifacts.
//!
//! The forged variants run hermetically on every checkout through the
//! reference interpreter (`testkit` + `runtime::interp`); the real
//! variants require `make artifacts` and announce themselves with a
//! single `skipped (artifacts not built)` line when the tree is
//! absent (allowed skips are listed in rust/README.md).

use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::protocol::{ErrorCode, Frame};
use fourier_compress::coordinator::{DeviceClient, EdgeServer, TcpTransport,
                                    Transport, CLIENT_CAPS};
use fourier_compress::net::Channel;
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::testkit::forged_store;
use std::sync::Arc;

fn real_root(test: &str) -> Option<std::path::PathBuf> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("skipped (artifacts not built): serving_e2e::{test}");
        None
    }
}

fn serve_config(store: &ArtifactStore, overrides: &[String]) -> ServeConfig {
    let mut args = vec![
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
    ];
    args.extend_from_slice(overrides);
    ServeConfig::load(None, &args).unwrap()
}

/// Two concurrent clients generate through the live server —
/// exercises the batcher, session manager, and per-session codec
/// engines end to end.  `require_completion` additionally asserts the
/// completion decodes to non-empty text — meaningful for the trained
/// real-artifact model, not for forged random weights (which may
/// legitimately emit an immediate special token).
fn serve_generate_roundtrip_body(store: Arc<ArtifactStore>,
                                 require_completion: bool) {
    let cfg = serve_config(&store, &[
        "max_batch=2".into(),
        "batch_deadline_us=500".into(),
    ]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();

    let mut handles = Vec::new();
    for cid in 0..2u64 {
        let addr = addr.clone();
        let store = store.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = DeviceClient::connect(
                &addr, &store, cid + 1, Channel::gbps(1.0, 50)).unwrap();
            let g = client.generate("Q mira hue ? A", 4).unwrap();
            assert!(g.steps >= 1, "no tokens generated");
            if require_completion {
                assert!(!g.completion.is_empty(),
                        "trained model produced no decodable text");
            }
            assert!(client.stats.bytes_sent > 0);
            // conjugate-symmetric packing must beat raw by ~bandwidth
            assert!(client.stats.compression_ratio() > 4.0,
                    "ratio {}", client.stats.compression_ratio());
            let stats = client.server_stats().unwrap();
            assert!(stats.contains("\"requests\""));
            client.bye().unwrap();
            g
        }));
    }
    let gens: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // the serving model must produce a decodable completion
    for g in &gens {
        assert!(g.steps >= 1);
    }

    assert!(server.metrics.requests.load(std::sync::atomic::Ordering::Relaxed) >= 2);
    server.shutdown();
}

/// A geometry the manifest does not serve must be refused with a
/// typed protocol Error, not a crash — driven over a raw
/// `TcpTransport` so the test pins the wire behaviour, not the
/// `DeviceClient` conveniences.
fn rejects_bad_bucket_body(store: Arc<ArtifactStore>) {
    let model = store
        .manifest
        .path("serving.model")
        .and_then(|v| v.as_str())
        .expect("serving.model")
        .to_string();
    let cfg = serve_config(&store, &[]);
    let server = EdgeServer::start(cfg, store).unwrap();

    let t = TcpTransport::connect(server.addr).unwrap();
    let (mut tx, mut rx) = Box::new(t).split().unwrap();
    tx.send(&Frame::hello(9, CLIENT_CAPS, model)).unwrap();
    match rx.recv().unwrap() {
        Frame::HelloAck { buckets, .. } => {
            assert!(!buckets.is_empty(), "ack must advertise geometry");
        }
        other => panic!("expected HelloAck, got {}", other.type_id()),
    }
    tx.send(&Frame::Activation {
        session: 9, request: 1, bucket: 999, true_len: 10, ks: 3, kd: 3,
        point: 0, packed: vec![0.0; 9],
        coded: vec![],
    }).unwrap();
    match rx.recv().unwrap() {
        Frame::Error { code, msg } => {
            assert_eq!(code, ErrorCode::BadRequest, "typed reject: {msg}");
            assert!(msg.contains("bucket"));
        }
        other => panic!("expected Error, got {}", other.type_id()),
    }
    tx.send(&Frame::Bye).unwrap();
    server.shutdown();
}

// ---------------------------------------------------------------------------
// forged (hermetic — always run, hard-assert)
// ---------------------------------------------------------------------------

#[test]
fn forged_serve_generate_roundtrip() {
    let store = Arc::new(forged_store("e2e_roundtrip").expect("forge artifacts"));
    serve_generate_roundtrip_body(store, false);
}

#[test]
fn forged_server_rejects_bad_bucket() {
    let store = Arc::new(forged_store("e2e_badbucket").expect("forge artifacts"));
    rejects_bad_bucket_body(store);
}

// ---------------------------------------------------------------------------
// real artifacts (python-built; skip loudly when absent)
// ---------------------------------------------------------------------------

#[test]
fn serve_generate_roundtrip() {
    let Some(root) = real_root("serve_generate_roundtrip") else { return };
    let store = Arc::new(ArtifactStore::open(root).unwrap());
    serve_generate_roundtrip_body(store, true);
}

#[test]
fn server_rejects_bad_bucket() {
    let Some(root) = real_root("server_rejects_bad_bucket") else { return };
    let store = Arc::new(ArtifactStore::open(root).unwrap());
    rejects_bad_bucket_body(store);
}
