//! End-to-end coordinator test: real TCP server + device client with
//! the fused (pallas-codec) artifacts.  Requires `make artifacts`.

use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::{DeviceClient, EdgeServer};
use fourier_compress::net::Channel;
use fourier_compress::runtime::ArtifactStore;
use std::sync::Arc;

fn artifacts_root() -> Option<std::path::PathBuf> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    root.join("manifest.json").exists().then_some(root)
}

#[test]
fn serve_generate_roundtrip() {
    let Some(root) = artifacts_root() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".into(),
        format!("artifacts={}", root.display()),
        "max_batch=2".into(),
        "batch_deadline_us=500".into(),
    ]).unwrap();
    let store = Arc::new(ArtifactStore::open(root).unwrap());
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();

    // two concurrent clients — exercises the batcher + session manager
    let mut handles = Vec::new();
    for cid in 0..2u64 {
        let addr = addr.clone();
        let store = store.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = DeviceClient::connect(
                &addr, &store, cid + 1, Channel::gbps(1.0, 50)).unwrap();
            let g = client.generate("Q mira hue ? A", 4).unwrap();
            assert!(g.steps >= 1, "no tokens generated");
            assert!(client.stats.bytes_sent > 0);
            // conjugate-symmetric packing must beat raw by ~bandwidth
            assert!(client.stats.compression_ratio() > 4.0,
                    "ratio {}", client.stats.compression_ratio());
            let stats = client.server_stats().unwrap();
            assert!(stats.contains("\"requests\""));
            client.bye().unwrap();
            g
        }));
    }
    let gens: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // the trained serving model must answer the fact-world question
    for g in &gens {
        assert!(!g.completion.is_empty());
    }

    assert!(server.metrics.requests.load(std::sync::atomic::Ordering::Relaxed) >= 2);
    server.shutdown();
}

#[test]
fn server_rejects_bad_bucket() {
    use fourier_compress::coordinator::protocol::Frame;
    use std::io::BufReader;
    let Some(root) = artifacts_root() else { return };
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".into(),
        format!("artifacts={}", root.display()),
    ]).unwrap();
    let store = Arc::new(ArtifactStore::open(root).unwrap());
    let server = EdgeServer::start(cfg, store).unwrap();

    let tcp = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(tcp.try_clone().unwrap());
    let mut w = tcp;
    Frame::Hello { session: 9, model: "llamette-m".into() }
        .write_to(&mut w).unwrap();
    Frame::Activation {
        session: 9, request: 1, bucket: 999, true_len: 10, ks: 3, kd: 3,
        packed: vec![0.0; 9],
    }.write_to(&mut w).unwrap();
    match Frame::read_from(&mut reader).unwrap() {
        Frame::Error { msg } => assert!(msg.contains("bucket")),
        other => panic!("expected Error, got {}", other.type_id()),
    }
    Frame::Bye.write_to(&mut w).unwrap();
    server.shutdown();
}
