//! Hermetic entropy-wire tests: the lossless `codec::wire` layer
//! negotiated via `caps::ENTROPY`, driven end to end through the live
//! server — token identity, the try-and-compare never-worse byte
//! contract, the mixed-version downgrade against a legacy (entropy
//! off) server, and the server-side metric / byte-split accounting.
//! All tests hard-assert on every checkout — no python, no XLA.

use fourier_compress::codec::stream::StreamConfig;
use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::protocol::caps;
use fourier_compress::coordinator::{DeviceClient, EdgeServer};
use fourier_compress::model::tokenizer;
use fourier_compress::net::Channel;
use fourier_compress::testkit::forged_store;
use fourier_compress::util::json;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn serve_config(store_root: &std::path::Path, overrides: &[String])
    -> ServeConfig {
    let mut args = vec![
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store_root.display()),
    ];
    args.extend_from_slice(overrides);
    ServeConfig::load(None, &args).unwrap()
}

const PROMPT: &str = "Q mira hue ? A";
const STEPS: usize = 8;

/// Drive one client for `STEPS` tokens and return them.
fn drive(client: &mut DeviceClient) -> Vec<i32> {
    let mut ctx = tokenizer::encode_prompt(PROMPT);
    let mut tokens = Vec::new();
    for _ in 0..STEPS {
        let (t, _) = client.step(&ctx).unwrap();
        ctx.push(t);
        tokens.push(t);
    }
    tokens
}

/// Recompute regime, entropy on vs off against the same server: the
/// coding is lossless (bit-identical tokens), never ships a larger
/// frame than raw (try-and-compare), and both sides account the
/// coded/raw split consistently — client stats, server counters, and
/// the per-bucket pre/post byte columns in the Stats JSON all agree.
#[test]
fn entropy_recompute_is_lossless_and_never_worse() {
    let store = Arc::new(forged_store("entropy_e2e").expect("forge"));
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();

    // baseline: raw frames (entropy negotiated but never enabled)
    let mut base = DeviceClient::connect(&addr, &store, 41,
                                         Channel::unlimited()).unwrap();
    assert!(base.server_caps() & caps::ENTROPY != 0,
            "server must advertise the entropy capability by default");
    assert!(!base.entropy_enabled());
    let base_tokens = drive(&mut base);
    let base_bytes = base.stats.bytes_sent;
    assert_eq!(base.stats.entropy_frames + base.stats.entropy_fallbacks, 0);
    base.bye().unwrap();
    let served_raw = server.metrics.entropy_frames.load(Ordering::Relaxed);
    assert_eq!(served_raw, 0, "raw client must not count as entropy");

    // entropy on: same prompt, same steps
    let mut ec = DeviceClient::connect(&addr, &store, 42,
                                       Channel::unlimited()).unwrap();
    assert!(ec.enable_entropy(),
            "handshake must negotiate the entropy capability");
    assert!(ec.entropy_enabled());
    let tokens = drive(&mut ec);
    assert_eq!(tokens, base_tokens,
               "entropy coding is lossless: tokens must be bit-identical");
    // try-and-compare: an entropy client never ships more bytes
    assert!(ec.stats.bytes_sent <= base_bytes,
            "entropy {} B vs raw {} B", ec.stats.bytes_sent, base_bytes);
    // every step was either coded or an explicit raw fallback
    assert_eq!(ec.stats.entropy_frames + ec.stats.entropy_fallbacks,
               STEPS as u64);
    // the coded frames' byte split is self-consistent and explains
    // the total savings exactly
    assert!(ec.stats.post_coding_bytes <= ec.stats.pre_coding_bytes);
    let saved = ec.stats.pre_coding_bytes - ec.stats.post_coding_bytes;
    assert_eq!(ec.stats.bytes_sent + saved, base_bytes,
               "client byte accounting does not reconcile");

    // server-side accounting mirrors the client exactly
    let m = &server.metrics;
    assert_eq!(m.entropy_frames.load(Ordering::Relaxed),
               ec.stats.entropy_frames);
    assert_eq!(m.entropy_bytes_saved.load(Ordering::Relaxed), saved);
    // a raw frame from a client that already sent coded ones (and
    // only such a client) counts as a server-observed fallback, so
    // the server can never see more fallbacks than the client took
    assert!(m.entropy_fallbacks.load(Ordering::Relaxed)
                <= ec.stats.entropy_fallbacks);

    // the Stats JSON carries the per-bucket pre/post coding split
    let stats = ec.server_stats().unwrap();
    let j = json::parse(&stats).unwrap();
    assert_eq!(j.usize_or("entropy_frames", usize::MAX) as u64,
               ec.stats.entropy_frames);
    let buckets = j.get("buckets").and_then(|b| b.as_arr()).expect("buckets");
    let (mut pre, mut post) = (0u64, 0u64);
    for b in buckets {
        pre += b.usize_or("pre_bytes", 0) as u64;
        post += b.usize_or("post_bytes", 0) as u64;
    }
    assert_eq!(pre, ec.stats.pre_coding_bytes,
               "bucket pre-coding split does not reconcile");
    assert_eq!(post, ec.stats.post_coding_bytes,
               "bucket post-coding split does not reconcile");
    ec.bye().unwrap();
    server.shutdown();
}

/// Mixed-version handshake: an ENTROPY-capable client against a
/// legacy server (entropy off) downgrades cleanly — `enable_entropy`
/// returns false, every frame crosses the wire raw, and the byte
/// stream is identical to what the same client produces when it never
/// asks for entropy at all (i.e. byte-identical pre-entropy frames).
#[test]
fn capable_client_downgrades_to_byte_identical_frames_on_legacy_server() {
    let store = Arc::new(forged_store("entropy_legacy").expect("forge"));

    // legacy server: the entropy capability withheld
    let legacy = EdgeServer::start(
        serve_config(&store.root, &["entropy=false".into()]),
        store.clone()).unwrap();
    let mut lc = DeviceClient::connect(&legacy.addr.to_string(), &store, 51,
                                       Channel::unlimited()).unwrap();
    assert_eq!(lc.server_caps() & caps::ENTROPY, 0);
    assert!(!lc.enable_entropy(),
            "enable_entropy must refuse without the negotiated capability");
    assert!(!lc.entropy_enabled());
    let legacy_tokens = drive(&mut lc);
    let legacy_bytes = lc.stats.bytes_sent;
    assert_eq!(lc.stats.entropy_frames + lc.stats.entropy_fallbacks, 0);
    lc.bye().unwrap();
    assert_eq!(legacy.metrics.entropy_frames.load(Ordering::Relaxed), 0);
    legacy.shutdown();

    // modern server, client never enabling entropy: the wire bytes
    // must be identical — the capability bit changes the HelloAck,
    // never a data frame, so the two runs' data traffic is
    // byte-for-byte the pre-entropy format
    let modern = EdgeServer::start(serve_config(&store.root, &[]),
                                   store.clone()).unwrap();
    let mut mc = DeviceClient::connect(&modern.addr.to_string(), &store, 51,
                                       Channel::unlimited()).unwrap();
    let modern_tokens = drive(&mut mc);
    assert_eq!(modern_tokens, legacy_tokens);
    assert_eq!(mc.stats.bytes_sent, legacy_bytes,
               "raw data frames must be byte-identical across the \
                capability divide");
    mc.bye().unwrap();
    modern.shutdown();
}

/// Stream mode with entropy: keyframes and sparse deltas both ride
/// the coded wire form, tokens stay bit-identical to the raw stream,
/// and the entropy layer shaves additional bytes off a regime that is
/// already delta-compressed.
#[test]
fn entropy_stream_mode_is_lossless_and_saves_bytes() {
    let store = Arc::new(forged_store("entropy_stream").expect("forge"));
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();
    let sc_cfg = StreamConfig { keyframe_interval: 64,
                                drift_threshold: 0.0 };

    // baseline: raw delta stream
    let mut base = DeviceClient::connect(&addr, &store, 61,
                                         Channel::unlimited()).unwrap();
    assert!(base.enable_stream(sc_cfg));
    let base_tokens = drive(&mut base);
    let base_bytes = base.stats.bytes_sent;
    base.bye().unwrap();

    // entropy-coded delta stream
    let mut ec = DeviceClient::connect(&addr, &store, 62,
                                       Channel::unlimited()).unwrap();
    assert!(ec.enable_stream(sc_cfg));
    assert!(ec.enable_entropy());
    let tokens = drive(&mut ec);
    assert_eq!(tokens, base_tokens, "entropy stream diverged from raw");
    assert_eq!(ec.stats.resyncs, 0);
    assert_eq!(ec.stats.key_frames + ec.stats.delta_frames, STEPS as u64);
    assert_eq!(ec.stats.entropy_frames + ec.stats.entropy_fallbacks,
               STEPS as u64);
    assert!(ec.stats.bytes_sent <= base_bytes,
            "entropy stream {} B vs raw stream {} B",
            ec.stats.bytes_sent, base_bytes);
    let saved = ec.stats.pre_coding_bytes - ec.stats.post_coding_bytes;
    assert_eq!(ec.stats.bytes_sent + saved, base_bytes,
               "stream byte accounting does not reconcile");
    assert_eq!(server.metrics.entropy_frames.load(Ordering::Relaxed),
               ec.stats.entropy_frames);
    ec.bye().unwrap();
    server.shutdown();
}
