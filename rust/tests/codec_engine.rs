//! CodecEngine refactor contract tests:
//!
//! 1. **Wire parity** — every codec's `compress_into` over a
//!    caller-owned engine emits byte-identical payloads to the legacy
//!    one-shot API, and both decompress to identical floats.
//! 2. **Golden snapshot** — the `fc` payload bytes for a fixed set of
//!    (shape, ratio) fixtures are pinned to a checked-in snapshot
//!    (self-bootstrapping on first run), so a future change that
//!    silently alters the wire format fails loudly.
//! 3. **Engine reuse** — repeated `compress_into`/`decompress_into`
//!    calls on the same shape do not grow the scratch arena after
//!    warm-up: the steady-state decode loop is allocation-free.

use fourier_compress::codec::{by_name, Codec, CodecEngine, Payload};
use fourier_compress::tensor::MatView;
use fourier_compress::util::rng::Rng;
use std::io::Write;
use std::path::PathBuf;

fn rand_act(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..rows * cols).map(|_| rng.normal() as f32).collect()
}

/// The fixture grid: shapes cover pow2 and bluestein axes.
const FIXTURES: &[(usize, usize, f64, u64)] = &[
    (16, 96, 6.0, 1),
    (48, 128, 8.0, 2),
    (64, 128, 8.0, 3),
    (31, 100, 4.0, 4),
];

#[test]
fn engine_payloads_match_legacy_for_every_codec() {
    // int8/none ignore ratio; factorization codecs are deterministic
    for name in ["fc", "topk", "qr", "fwsvd", "asvd", "svdllm", "int8", "none"] {
        let c = by_name(name).unwrap();
        let mut eng = CodecEngine::new();
        let mut p = Payload::empty();
        let mut rec = Vec::new();
        for &(rows, cols, ratio, seed) in FIXTURES {
            let a = rand_act(rows, cols, seed);
            let legacy = c.compress(&a, rows, cols, ratio).unwrap();
            c.compress_into(&mut eng, MatView::new(&a, rows, cols), ratio,
                            &mut p).unwrap();
            assert_eq!(p, legacy, "{name} {rows}x{cols} r{ratio}");
            assert_eq!(p.achieved_ratio(), legacy.achieved_ratio(), "{name}");

            c.decompress_into(&mut eng, &p, &mut rec).unwrap();
            assert_eq!(rec, c.decompress(&legacy).unwrap(),
                       "{name} {rows}x{cols} decompress");
        }
    }
}

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("fc_golden.bin")
}

/// Concatenated fc payload bodies over the fixture grid, each
/// length-prefixed (u32 le).
fn fc_snapshot_bytes() -> Vec<u8> {
    let fc = by_name("fc").unwrap();
    let mut out = Vec::new();
    for &(rows, cols, ratio, seed) in FIXTURES {
        let a = rand_act(rows, cols, seed);
        let p = fc.compress(&a, rows, cols, ratio).unwrap();
        out.extend_from_slice(&(p.body.len() as u32).to_le_bytes());
        out.extend_from_slice(&p.body);
    }
    out
}

#[test]
fn fc_golden_snapshot_bytes_stable() {
    let got = fc_snapshot_bytes();
    let path = snapshot_path();
    match std::fs::read(&path) {
        Ok(want) => {
            assert_eq!(got.len(), want.len(),
                       "fc wire format drifted from {}", path.display());
            assert!(got == want,
                    "fc payload bytes drifted from {}", path.display());
        }
        Err(_) => {
            // first run on this tree: bootstrap the snapshot
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            let mut f = std::fs::File::create(&path).unwrap();
            f.write_all(&got).unwrap();
            eprintln!("bootstrapped fc golden snapshot at {}", path.display());
        }
    }
}

#[test]
fn engine_scratch_stops_growing_after_warmup() {
    let fc = by_name("fc").unwrap();
    let (rows, cols, ratio) = (64usize, 256usize, 8.0);
    let a = rand_act(rows, cols, 9);
    let view = MatView::new(&a, rows, cols);

    let mut eng = CodecEngine::new();
    let mut p = Payload::empty();
    let mut rec = Vec::new();
    // warm-up: two full round trips grow the arena to steady state
    for _ in 0..2 {
        fc.compress_into(&mut eng, view, ratio, &mut p).unwrap();
        fc.decompress_into(&mut eng, &p, &mut rec).unwrap();
    }
    let warm = eng.scratch_bytes();
    let (warm_plans, warm_idx) = (eng.cached_plans(), eng.cached_index_sets());
    assert!(warm > 0, "engine never allocated scratch");

    for _ in 0..100 {
        fc.compress_into(&mut eng, view, ratio, &mut p).unwrap();
        fc.decompress_into(&mut eng, &p, &mut rec).unwrap();
        assert_eq!(eng.scratch_bytes(), warm, "scratch arena grew");
    }
    assert_eq!(eng.cached_plans(), warm_plans, "plan cache churned");
    assert_eq!(eng.cached_index_sets(), warm_idx, "index cache churned");
}

#[test]
fn engine_serves_mixed_shapes_without_confusion() {
    // a server-side engine sees interleaved buckets; results must not
    // depend on call order (scratch is re-zeroed per call)
    let fc = by_name("fc").unwrap();
    let mut eng = CodecEngine::new();
    let mut p = Payload::empty();
    let mut rec = Vec::new();

    let shapes = [(16usize, 96usize, 6.0f64, 21u64), (64, 128, 8.0, 22),
                  (31, 100, 4.0, 23)];
    // reference outputs from fresh engines
    let mut want = Vec::new();
    for &(r, c, ratio, seed) in &shapes {
        let a = rand_act(r, c, seed);
        let payload = fc.compress(&a, r, c, ratio).unwrap();
        let out = fc.decompress(&payload).unwrap();
        want.push((a, payload, out));
    }
    // interleave through one shared engine, twice
    for _ in 0..2 {
        for (i, &(r, c, ratio, _)) in shapes.iter().enumerate() {
            let (a, wp, wo) = &want[i];
            fc.compress_into(&mut eng, MatView::new(a, r, c), ratio, &mut p)
                .unwrap();
            assert_eq!(&p, wp, "shape {r}x{c} payload drifted");
            fc.decompress_into(&mut eng, &p, &mut rec).unwrap();
            assert_eq!(&rec, wo, "shape {r}x{c} recon drifted");
        }
    }
}

#[test]
fn wire_ratio_accounts_for_frame_header() {
    let fc = by_name("fc").unwrap();
    let a = rand_act(48, 128, 5);
    let p = fc.compress(&a, 48, 128, 8.0).unwrap();
    let raw = (48 * 128 * 4) as f64;
    assert_eq!(p.wire_bytes(), p.body.len() + 12);
    assert!((p.achieved_ratio() - raw / p.body.len() as f64).abs() < 1e-12);
    assert!((p.wire_ratio() - raw / (p.body.len() + 12) as f64).abs() < 1e-12);
    assert!(p.wire_ratio() < p.achieved_ratio());
}
