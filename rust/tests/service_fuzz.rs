//! Service state-machine fuzz: drive `ServingService::handle` with
//! seeded random interleavings of valid, corrupt, out-of-order, and
//! ladder-switch frames — handshakes mid-stream, deltas before
//! keyframes, foreign sessions, bogus buckets/points/geometries,
//! client-bound frame types — and assert the service never panics and
//! only ever answers with typed protocol frames (`Frame::Error` with
//! a defined code, `HelloAck`, or `Stats`).  Afterwards the same
//! service must still serve a clean generation: fuzz traffic may be
//! rejected, never wedge the core.

use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::protocol::{Frame, PROTOCOL_MAGIC,
                                              PROTOCOL_VERSION};
use fourier_compress::coordinator::{start_service, DeviceClient, Response,
                                    CLIENT_CAPS};
use fourier_compress::testkit::forged_store;
use fourier_compress::util::rng::Rng;
use std::sync::mpsc;
use std::sync::Arc;

/// One random frame, biased toward the interesting arms: data frames
/// with a mix of correct and corrupt sessions/buckets/points, stream
/// sequences that jump around, and occasional handshakes.
fn random_frame(rng: &mut Rng, session: u64, geoms: &[(u16, u16, u16)])
    -> Frame {
    let &(bucket, ks, kd) = rng.choice(geoms);
    // half the frames aim at real geometry, half corrupt something
    let corrupt = rng.below(2) == 0;
    let (bucket, ks, kd) = if corrupt {
        match rng.below(3) {
            0 => (rng.below(2000) as u16, ks, kd),
            1 => (bucket, rng.below(64) as u16, rng.below(64) as u16),
            _ => (bucket, ks, kd),
        }
    } else {
        (bucket, ks, kd)
    };
    let session = if corrupt && rng.below(3) == 0 {
        rng.next_u64()
    } else {
        session
    };
    let point = rng.below(5) as u8; // 0..=2 valid, 3..=4 not
    let n = ks as usize * kd as usize;
    match rng.below(10) {
        0 => Frame::Hello {
            magic: if rng.below(4) == 0 { rng.next_u64() as u32 }
                   else { PROTOCOL_MAGIC },
            version: if rng.below(4) == 0 { rng.below(100) as u16 }
                     else { PROTOCOL_VERSION },
            caps: if rng.below(2) == 0 { CLIENT_CAPS }
                  else { rng.next_u64() as u32 },
            session,
            model: "forge-tiny".into(),
        },
        1..=3 => Frame::Activation {
            session,
            request: rng.next_u64(),
            bucket,
            true_len: rng.below(70) as u16,
            ks,
            kd,
            point,
            packed: (0..if rng.below(3) == 0 { rng.below(n.max(1) * 2) }
                        else { n })
                .map(|_| rng.normal() as f32)
                .collect(),
        },
        4..=7 => {
            let keyframe = rng.below(2) == 0;
            Frame::Delta {
                session,
                request: rng.next_u64(),
                seq: rng.below(6) as u32, // small: gaps AND matches occur
                keyframe,
                bucket,
                true_len: rng.below(70) as u16,
                ks,
                kd,
                point,
                packed: if keyframe {
                    (0..n).map(|_| rng.normal() as f32).collect()
                } else {
                    vec![]
                },
                updates: if keyframe {
                    vec![]
                } else {
                    (0..rng.below(6))
                        .map(|_| {
                            // in-range and wildly out-of-range indices
                            let i = if rng.below(3) == 0 {
                                rng.next_u64() as u32
                            } else {
                                rng.below(n.max(1)) as u32
                            };
                            (i, rng.normal() as f32)
                        })
                        .collect()
                },
            }
        }
        8 => Frame::GetStats,
        // client-bound frames a rogue peer might echo back
        _ => match rng.below(3) {
            0 => Frame::Token { request: rng.next_u64(), token: 1,
                                logprob: 0.0 },
            1 => Frame::Stats { json: "{}".into() },
            _ => Frame::HelloAck { version: PROTOCOL_VERSION, caps: 0,
                                   buckets: vec![] },
        },
    }
}

#[test]
fn random_frame_interleavings_never_panic_and_stay_typed() {
    let store = Arc::new(forged_store("svc_fuzz").expect("forge artifacts"));
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
        "session_ttl_s=60".into(),
    ]).unwrap();
    let handle = start_service(&cfg, store.clone()).unwrap();
    let service = handle.service();

    // the real serving geometry (bucket, ks, kd) from the manifest
    let bmap = store.manifest.path("serving.buckets")
        .and_then(|b| b.as_obj()).expect("buckets");
    let geoms: Vec<(u16, u16, u16)> = bmap
        .iter()
        .map(|(bstr, bj)| (bstr.parse().unwrap(),
                           bj.usize_or("ks", 0) as u16,
                           bj.usize_or("kd", 0) as u16))
        .collect();

    let mut rng = Rng::new(0xF0_55);
    for round in 0..8u64 {
        let (reply_tx, reply_rx) = mpsc::channel::<Frame>();
        let mut conn = service.open_conn(reply_tx, format!("fuzz-{round}"));
        let session = 9000 + round;
        // half the rounds start with a legitimate handshake so the
        // fuzz also exercises the post-handshake state machine
        // (including ladder switches mid-stream); the rest hammer the
        // pre-handshake gate
        if round % 2 == 0 {
            match service.handle(&mut conn,
                                 Frame::hello(session, CLIENT_CAPS,
                                              "forge-tiny")) {
                Response::Reply(Frame::HelloAck { .. }) => {}
                _ => panic!("round {round}: handshake refused"),
            }
        }
        for i in 0..400 {
            let frame = random_frame(&mut rng, session, &geoms);
            match service.handle(&mut conn, frame) {
                Response::None => {}
                Response::Close => panic!(
                    "round {round} frame {i}: fuzz input closed the \
                     connection (only Bye / shutdown may)"),
                Response::Reply(f) => match f {
                    Frame::Error { .. } | Frame::HelloAck { .. }
                    | Frame::Stats { .. } => {}
                    other => panic!("round {round} frame {i}: service \
                                     replied with frame type {}",
                                    other.type_id()),
                },
            }
        }
        // Bye closes cleanly
        assert!(matches!(service.handle(&mut conn, Frame::Bye),
                         Response::Close));
        service.close_conn(&conn);
        drop(conn);
        // drain whatever the batcher workers produced for this round
        while reply_rx.try_recv().is_ok() {}
    }

    // the core survived: a well-behaved client still generates
    let mut client = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 1).unwrap();
    let g = client.generate("Q mira hue ? A", 3).unwrap();
    assert!(g.steps >= 1, "service wedged by fuzz traffic");
    client.bye().unwrap();
    handle.shutdown();
}
