//! Service state-machine fuzz: drive `ServingService::handle` with
//! seeded random interleavings of valid, corrupt, out-of-order, and
//! ladder-switch frames — handshakes mid-stream, deltas before
//! keyframes, prefill chunks with random indices and bodies, foreign
//! sessions, bogus buckets/points/geometries,
//! client-bound frame types — and assert the service never panics and
//! only ever answers with typed protocol frames (`Frame::Error` with
//! a defined code, `HelloAck`, or `Stats`).  Afterwards the same
//! service must still serve a clean generation: fuzz traffic may be
//! rejected, never wedge the core.

use fourier_compress::codec::wire;
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::protocol::{ErrorCode, Frame,
                                              PROTOCOL_MAGIC,
                                              PROTOCOL_VERSION};
use fourier_compress::coordinator::{start_service, DeviceClient, EdgeServer,
                                    Reply, Response, Transport, CLIENT_CAPS};
use fourier_compress::testkit::forged_store;
use fourier_compress::util::rng::Rng;
use std::io::{Read, Write};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// The real serving geometry (bucket, ks, kd) from the manifest.
fn manifest_geoms(store: &ArtifactStore) -> Vec<(u16, u16, u16)> {
    store.manifest.path("serving.buckets")
        .and_then(|b| b.as_obj())
        .expect("buckets")
        .iter()
        .map(|(bstr, bj)| (bstr.parse().unwrap(),
                           bj.usize_or("ks", 0) as u16,
                           bj.usize_or("kd", 0) as u16))
        .collect()
}

/// A random entropy-coded body: usually a valid coding of random
/// data, often corrupted afterwards — a flipped mode byte, a bit flip
/// anywhere (headers, Rice parameter, bitstream), or a truncated
/// tail.  Whatever comes out, the service must answer with a typed
/// reject or a token, never panic.
fn random_coded(rng: &mut Rng, n: usize, updates: bool) -> Vec<u8> {
    let mut coded = Vec::new();
    if updates {
        let mut idx = 0u32;
        let ups: Vec<(u32, f32)> = (0..rng.below(8))
            .map(|_| {
                idx += 1 + rng.below(9) as u32;
                (idx, rng.normal() as f32)
            })
            .collect();
        wire::encode_updates(&ups, &mut coded);
    } else {
        let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        wire::encode_f32_plane(&vals, &mut coded);
    }
    match rng.below(4) {
        0 => {} // valid
        1 => coded[0] = rng.below(256) as u8, // random mode byte
        2 => {
            // single bit flip anywhere: count, Rice k, bitstream...
            let i = rng.below(coded.len());
            coded[i] ^= 1 << rng.below(8);
        }
        _ => {
            coded.truncate(rng.below(coded.len()));
            if coded.is_empty() {
                // the wire flag demands a non-empty coded body
                coded.push(rng.below(256) as u8);
            }
        }
    }
    coded
}

/// One random frame, biased toward the interesting arms: data frames
/// with a mix of correct and corrupt sessions/buckets/points, stream
/// sequences that jump around, and occasional handshakes.
fn random_frame(rng: &mut Rng, session: u64, geoms: &[(u16, u16, u16)])
    -> Frame {
    let &(bucket, ks, kd) = rng.choice(geoms);
    // half the frames aim at real geometry, half corrupt something
    let corrupt = rng.below(2) == 0;
    let (bucket, ks, kd) = if corrupt {
        match rng.below(3) {
            0 => (rng.below(2000) as u16, ks, kd),
            1 => (bucket, rng.below(64) as u16, rng.below(64) as u16),
            _ => (bucket, ks, kd),
        }
    } else {
        (bucket, ks, kd)
    };
    let session = if corrupt && rng.below(3) == 0 {
        rng.next_u64()
    } else {
        session
    };
    let point = rng.below(5) as u8; // 0..=2 valid, 3..=4 not
    let n = ks as usize * kd as usize;
    match rng.below(12) {
        0 => Frame::Hello {
            magic: if rng.below(4) == 0 { rng.next_u64() as u32 }
                   else { PROTOCOL_MAGIC },
            version: if rng.below(4) == 0 { rng.below(100) as u16 }
                     else { PROTOCOL_VERSION },
            caps: if rng.below(2) == 0 { CLIENT_CAPS }
                  else { rng.next_u64() as u32 },
            session,
            model: "forge-tiny".into(),
        },
        1..=3 => {
            // a quarter of activations ride the entropy-coded wire
            // form (valid or corrupted) instead of a raw plane
            let coded = if rng.below(4) == 0 {
                random_coded(rng, n.clamp(1, 64), false)
            } else {
                vec![]
            };
            Frame::Activation {
                session,
                request: rng.next_u64(),
                bucket,
                true_len: rng.below(70) as u16,
                ks,
                kd,
                point,
                packed: if coded.is_empty() {
                    (0..if rng.below(3) == 0 { rng.below(n.max(1) * 2) }
                        else { n })
                        .map(|_| rng.normal() as f32)
                        .collect()
                } else {
                    vec![]
                },
                coded,
            }
        }
        4..=7 => {
            let keyframe = rng.below(2) == 0;
            let coded = if rng.below(4) == 0 {
                random_coded(rng, n.clamp(1, 64), !keyframe)
            } else {
                vec![]
            };
            Frame::Delta {
                session,
                request: rng.next_u64(),
                seq: rng.below(6) as u32, // small: gaps AND matches occur
                keyframe,
                bucket,
                true_len: rng.below(70) as u16,
                ks,
                kd,
                point,
                packed: if keyframe && coded.is_empty() {
                    (0..n).map(|_| rng.normal() as f32).collect()
                } else {
                    vec![]
                },
                updates: if keyframe || !coded.is_empty() {
                    vec![]
                } else {
                    (0..rng.below(6))
                        .map(|_| {
                            // in-range and wildly out-of-range indices
                            let i = if rng.below(3) == 0 {
                                rng.next_u64() as u32
                            } else {
                                rng.below(n.max(1)) as u32
                            };
                            (i, rng.normal() as f32)
                        })
                        .collect()
                },
                coded,
            }
        }
        8 => Frame::GetStats,
        9..=10 => {
            // prompt-phase chunks: random indices (gaps, duplicates,
            // and matches), truncated/oversized keyframe bodies,
            // out-of-range update indices, premature `last` flags
            let keyframe = rng.below(2) == 0;
            let coded = if rng.below(4) == 0 {
                random_coded(rng, n.clamp(1, 64), !keyframe)
            } else {
                vec![]
            };
            Frame::PrefillChunk {
                session,
                request: rng.next_u64(),
                bucket,
                true_len: rng.below(70) as u16,
                ks,
                kd,
                point,
                index: rng.below(6) as u32,
                last: rng.below(3) == 0,
                keyframe,
                packed: if keyframe && coded.is_empty() {
                    (0..if rng.below(3) == 0 { rng.below(n.max(1) * 2) }
                        else { n })
                        .map(|_| rng.normal() as f32)
                        .collect()
                } else {
                    vec![]
                },
                updates: if keyframe || !coded.is_empty() {
                    vec![]
                } else {
                    (0..rng.below(6))
                        .map(|_| {
                            let i = if rng.below(3) == 0 {
                                rng.next_u64() as u32
                            } else {
                                rng.below(n.max(1)) as u32
                            };
                            (i, rng.normal() as f32)
                        })
                        .collect()
                },
                coded,
            }
        }
        // client-bound frames a rogue peer might echo back
        _ => match rng.below(3) {
            0 => Frame::Token { request: rng.next_u64(), token: 1,
                                logprob: 0.0 },
            1 => Frame::Stats { json: "{}".into() },
            _ => Frame::HelloAck { version: PROTOCOL_VERSION, caps: 0,
                                   buckets: vec![] },
        },
    }
}

#[test]
fn random_frame_interleavings_never_panic_and_stay_typed() {
    let store = Arc::new(forged_store("svc_fuzz").expect("forge artifacts"));
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
        "session_ttl_s=60".into(),
    ]).unwrap();
    let handle = start_service(&cfg, store.clone()).unwrap();
    let service = handle.service();
    let geoms = manifest_geoms(&store);

    let mut rng = Rng::new(0xF0_55);
    for round in 0..8u64 {
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        let mut conn = service.open_conn(reply_tx, format!("fuzz-{round}"));
        let session = 9000 + round;
        // half the rounds start with a legitimate handshake so the
        // fuzz also exercises the post-handshake state machine
        // (including ladder switches mid-stream); the rest hammer the
        // pre-handshake gate
        if round % 2 == 0 {
            match service.handle(&mut conn,
                                 Frame::hello(session, CLIENT_CAPS,
                                              "forge-tiny")) {
                Response::Reply(Frame::HelloAck { .. }) => {}
                _ => panic!("round {round}: handshake refused"),
            }
        }
        for i in 0..400 {
            let frame = random_frame(&mut rng, session, &geoms);
            match service.handle(&mut conn, frame) {
                Response::None => {}
                Response::Close => panic!(
                    "round {round} frame {i}: fuzz input closed the \
                     connection (only Bye / shutdown may)"),
                Response::Reply(f) => match f {
                    Frame::Error { .. } | Frame::HelloAck { .. }
                    | Frame::Stats { .. } => {}
                    other => panic!("round {round} frame {i}: service \
                                     replied with frame type {}",
                                    other.type_id()),
                },
            }
        }
        // Bye closes cleanly
        assert!(matches!(service.handle(&mut conn, Frame::Bye),
                         Response::Close));
        service.close_conn(&conn);
        drop(conn);
        // drain whatever the batcher workers produced for this round
        while reply_rx.try_recv().is_ok() {}
    }

    // the core survived: a well-behaved client still generates
    let mut client = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 1).unwrap();
    let g = client.generate("Q mira hue ? A", 3).unwrap();
    assert!(g.steps >= 1, "service wedged by fuzz traffic");
    client.bye().unwrap();
    handle.shutdown();
}

/// A peer that ships entropy-coded frames to a server that never
/// advertised [`caps::ENTROPY`] (`entropy=false`) gets a typed
/// BadRequest naming the missing capability — on both data arms —
/// and the connection keeps working on raw frames afterwards.
#[test]
fn entropy_frames_to_a_legacy_server_are_typed_rejects() {
    let store = Arc::new(forged_store("entropy_fuzz").expect("forge artifacts"));
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
        "entropy=false".into(),
    ]).unwrap();
    let handle = start_service(&cfg, store.clone()).unwrap();
    let service = handle.service();
    let geoms = manifest_geoms(&store);
    let &(bucket, ks, kd) = &geoms[0];
    let n = ks as usize * kd as usize;

    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let mut conn = service.open_conn(reply_tx, "entropy-fuzz".into());
    assert!(matches!(
        service.handle(&mut conn, Frame::hello(5, CLIENT_CAPS, "forge-tiny")),
        Response::Reply(Frame::HelloAck { .. })));

    let mut rng = Rng::new(0xE17);
    let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut coded = Vec::new();
    wire::encode_f32_plane(&vals, &mut coded);
    let act = Frame::Activation {
        session: 5, request: 1, bucket, true_len: 3, ks, kd, point: 0,
        packed: vec![], coded: coded.clone(),
    };
    match service.handle(&mut conn, act) {
        Response::Reply(Frame::Error { code: ErrorCode::BadRequest, msg }) => {
            assert!(msg.contains("entropy"), "unexpected reject: {msg}");
        }
        _ => panic!("coded Activation to a non-entropy server must be a \
                     typed BadRequest"),
    }
    let delta = Frame::Delta {
        session: 5, request: 2, seq: 0, keyframe: true, bucket, true_len: 3,
        ks, kd, point: 0, packed: vec![], updates: vec![], coded,
    };
    match service.handle(&mut conn, delta) {
        Response::Reply(Frame::Error { code: ErrorCode::BadRequest, msg }) => {
            assert!(msg.contains("entropy"), "unexpected reject: {msg}");
        }
        _ => panic!("coded Delta to a non-entropy server must be a typed \
                     BadRequest"),
    }
    // raw frames on the same connection still serve
    let raw = Frame::Activation {
        session: 5, request: 3, bucket, true_len: 3, ks, kd, point: 0,
        packed: vals, coded: vec![],
    };
    assert!(matches!(service.handle(&mut conn, raw), Response::None),
            "raw frame after entropy rejects must still serve");
    service.close_conn(&conn);
    drop(conn);
    while reply_rx.try_recv().is_ok() {}
    handle.shutdown();
}

/// Prefill chunks at a server that never advertised `caps::PREFILL`
/// (`prefill=false`) are typed BadRequests naming the capability;
/// at a capable server, out-of-order, duplicate, and truncated chunks
/// are typed StreamRejects (or swallowed silently inside a doomed
/// burst), never panics — and the service still generates afterwards.
#[test]
fn prefill_chaos_is_typed_rejects_and_never_wedges_the_service() {
    use fourier_compress::codec::stream::{split_prefill, BlockGeom,
                                          PrefillConfig};
    use fourier_compress::codec::CodecEngine;
    use fourier_compress::testkit::forged_longctx_store;

    // legacy server: the prefill capability withheld
    let store =
        Arc::new(forged_store("prefill_fuzz_legacy").expect("forge artifacts"));
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
        "prefill=false".into(),
    ]).unwrap();
    let handle = start_service(&cfg, store.clone()).unwrap();
    let service = handle.service();
    let geoms = manifest_geoms(&store);
    let &(bucket, ks, kd) = &geoms[0];
    let n = ks as usize * kd as usize;

    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let mut conn = service.open_conn(reply_tx, "prefill-fuzz".into());
    assert!(matches!(
        service.handle(&mut conn, Frame::hello(6, CLIENT_CAPS, "forge-tiny")),
        Response::Reply(Frame::HelloAck { .. })));
    let chunk = |request: u64, index: u32, last: bool, keyframe: bool,
                 packed: Vec<f32>, updates: Vec<(u32, f32)>| {
        Frame::PrefillChunk {
            session: 6, request, bucket, true_len: 3, ks, kd, point: 0,
            index, last, keyframe, packed, updates, coded: vec![],
        }
    };
    let mut rng = Rng::new(0xF111);
    let plane: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    match service.handle(&mut conn,
                         chunk(1, 0, true, true, plane.clone(), vec![])) {
        Response::Reply(Frame::Error { code: ErrorCode::BadRequest, msg }) =>
            assert!(msg.contains("prefill"), "unexpected reject: {msg}"),
        _ => panic!("prefill chunk to a non-PREFILL server must be a typed \
                     BadRequest"),
    }
    // raw frames on the same connection still serve
    let raw = Frame::Activation {
        session: 6, request: 2, bucket, true_len: 3, ks, kd, point: 0,
        packed: plane.clone(), coded: vec![],
    };
    assert!(matches!(service.handle(&mut conn, raw), Response::None));
    service.close_conn(&conn);
    drop(conn);
    while reply_rx.try_recv().is_ok() {}
    handle.shutdown();

    // capable server: out-of-order, duplicate, and truncated chunks,
    // on the long-context store whose small bucket gives a
    // multi-chunk plane
    let store = Arc::new(forged_longctx_store("prefill_fuzz_chaos")
        .expect("forge artifacts"));
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
    ]).unwrap();
    let handle = start_service(&cfg, store.clone()).unwrap();
    let service = handle.service();
    let (bucket, ks, kd) = *manifest_geoms(&store).iter().min()
        .expect("at least one bucket");
    let n = ks as usize * kd as usize;

    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let mut conn = service.open_conn(reply_tx, "prefill-chaos".into());
    assert!(matches!(
        service.handle(&mut conn,
                       Frame::hello(8, CLIENT_CAPS, "forge-longctx")),
        Response::Reply(Frame::HelloAck { .. })));
    let chunk = |request: u64, index: u32, last: bool, keyframe: bool,
                 packed: Vec<f32>, updates: Vec<(u32, f32)>| {
        Frame::PrefillChunk {
            session: 8, request, bucket, true_len: 3, ks, kd, point: 0,
            index, last, keyframe, packed, updates, coded: vec![],
        }
    };
    let plane: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut eng = CodecEngine::new();
    let geom = BlockGeom { rows: bucket as usize, cols: 32,
                           ks: ks as usize, kd: kd as usize };
    let (mut chunks, mut state) = (Vec::new(), Vec::new());
    split_prefill(&mut eng, geom, &plane,
                  PrefillConfig { chunk_rows: 1, drift_threshold: 0.0 },
                  &mut chunks, &mut state).unwrap();
    assert!(chunks.len() >= 3, "need a multi-chunk sequence to disorder");
    macro_rules! expect_reject {
        ($f:expr, $what:expr) => {
            match service.handle(&mut conn, $f) {
                Response::Reply(Frame::Error {
                    code: ErrorCode::StreamReject, msg }) =>
                    assert!(msg.contains("prefill"), "{}: {msg}", $what),
                _ => panic!("{} must be a typed StreamReject", $what),
            }
        };
    }

    // out-of-order: a mid-sequence chunk with no chunk 0 first
    let c = &chunks[1];
    expect_reject!(chunk(3, c.index, c.last, c.keyframe, c.packed.clone(),
                         c.updates.clone()),
                   "chunk before any keyframe chunk 0");
    // duplicate: chunk 0, chunk 1, chunk 1 again → sequence gap
    for c in &chunks[..2] {
        assert!(matches!(
            service.handle(&mut conn,
                           chunk(4, c.index, c.last, c.keyframe,
                                 c.packed.clone(), c.updates.clone())),
            Response::None));
    }
    let c = &chunks[1];
    expect_reject!(chunk(4, c.index, c.last, c.keyframe, c.packed.clone(),
                         c.updates.clone()),
                   "duplicate chunk");
    // the rest of the doomed burst is swallowed, not a reject storm
    let c = &chunks[2];
    assert!(matches!(
        service.handle(&mut conn,
                       chunk(4, c.index, c.last, c.keyframe,
                             c.packed.clone(), c.updates.clone())),
        Response::None));
    // truncated: a restart whose keyframe chunk 0 carries ragged rows
    expect_reject!(chunk(5, 0, false, true, plane[..geom.kd + 1].to_vec(),
                         vec![]),
                   "truncated keyframe chunk");
    service.close_conn(&conn);
    drop(conn);
    while reply_rx.try_recv().is_ok() {}

    // the core survived: a well-behaved prefill client still generates
    let mut client = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 1).unwrap();
    assert!(client.enable_prefill(PrefillConfig { chunk_rows: 1,
                                                  drift_threshold: 0.0 }));
    let g = client.generate("Q mira hue ? A", 3).unwrap();
    assert!(g.steps >= 1, "service wedged by prefill chaos");
    client.bye().unwrap();
    handle.shutdown();
}

#[test]
fn poll_loop_survives_fuzz_disconnects_and_raw_bytes() {
    // the same fuzz pressure, but through the event-driven path: many
    // registered connections interleaved by the shared poll workers,
    // peers that vanish mid-generation without a Bye, and raw TCP
    // writes of garbage, oversized, and half-written frames — the
    // service must never panic, reply only with typed frames, and
    // still serve a clean generation afterwards
    let store = Arc::new(forged_store("poll_fuzz").expect("forge artifacts"));
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
        "poll_workers=2".into(),
        "compute_units=1".into(),
        "idle_deadline_ms=2000".into(),
    ]).unwrap();
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr;
    let geoms = manifest_geoms(&store);

    // phase 1: 8 in-proc fuzz peers hammer the poll loop concurrently
    std::thread::scope(|scope| {
        for t in 0..8u64 {
            let server = &server;
            let geoms = &geoms;
            scope.spawn(move || {
                let mut rng = Rng::new(0xED_F0 + t);
                let (mut tx, mut rx) =
                    (Box::new(server.connect_inproc()) as Box<dyn Transport>)
                        .split()
                        .unwrap();
                let session = 7000 + t;
                // half the peers handshake first so valid generation
                // traffic flows through the poll loop alongside junk
                if t % 2 == 0 {
                    let _ = tx.send(&Frame::hello(session, CLIENT_CAPS,
                                                  "forge-tiny"));
                }
                for i in 0..250u64 {
                    let frame = random_frame(&mut rng, session, geoms);
                    if tx.send(&frame).is_err() {
                        break; // server retired us (fine) — stop talking
                    }
                    if i % 16 == 0 {
                        while let Ok(Some(reply)) = rx.try_recv() {
                            match reply {
                                Frame::Token { .. } | Frame::Error { .. }
                                | Frame::HelloAck { .. }
                                | Frame::Stats { .. } => {}
                                other => panic!(
                                    "peer {t}: server sent frame type {}",
                                    other.type_id()),
                            }
                        }
                    }
                }
                // mid-generation disconnect: no Bye, just vanish —
                // dropping tx+rx severs both in-proc channels
            });
        }
    });

    // phase 2: raw TCP bytes straight at the listener
    let hello = Frame::hello(42, CLIENT_CAPS, "forge-tiny").encode();
    let raw_cases: Vec<Vec<u8>> = vec![
        b"\xde\xad\xbe\xef garbage that is not a frame".to_vec(),
        // plausible header (len 16, type 1) but only 5 body bytes,
        // then disconnect: a half-written frame
        {
            let mut v = vec![16, 0, 0, 0, 1];
            v.extend_from_slice(&[9, 9, 9, 9, 9]);
            v
        },
        // a length prefix far past MAX_FRAME
        vec![0xff, 0xff, 0xff, 0xff, 2],
        // connect-and-vanish
        vec![],
        // a valid Hello truncated mid-body
        hello[..hello.len() / 2].to_vec(),
    ];
    for (i, case) in raw_cases.iter().enumerate() {
        let mut s = std::net::TcpStream::connect(addr)
            .unwrap_or_else(|e| panic!("raw case {i}: connect: {e}"));
        let _ = s.write_all(case);
        drop(s); // half-written frames end in a disconnect
    }

    // phase 3: a byte-dribbled (but complete) Hello must still be
    // reassembled by the poll loop and answered with a HelloAck
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for chunk in hello.chunks(3) {
        s.write_all(chunk).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut header = [0u8; 5];
    s.read_exact(&mut header)
        .expect("no reply to a dribbled handshake");
    let ack_type = Frame::HelloAck { version: PROTOCOL_VERSION, caps: 0,
                                     buckets: vec![] }.type_id();
    assert_eq!(header[4], ack_type,
               "dribbled Hello answered with frame type {}", header[4]);
    drop(s);

    // the service is unwedged: a well-behaved client still generates,
    // and the fuzz connections all retired
    let mut client = DeviceClient::connect_over(
        Box::new(server.connect_inproc()), &store, 1).unwrap();
    let g = client.generate("Q mira hue ? A", 3).unwrap();
    assert!(g.steps >= 1, "service wedged by poll-loop fuzz");
    client.bye().unwrap();
    let m = &server.metrics;
    assert!(m.conns_opened.load(std::sync::atomic::Ordering::Relaxed) >= 9);
    server.shutdown();
}
