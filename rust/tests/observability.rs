//! End-to-end observability: the Stats frame's sharded metric
//! families reconcile with client-side accounting over both in-proc
//! and TCP transports, per-step traces carry the deterministic span
//! both ends mint from (session, request), a poisoned delta frame is
//! diagnosable from the flight-recorder dump alone, the snapshot
//! timeline emits schema-stable JSONL deltas, and a hung peer leaves
//! the other poll workers' occupancy gauges unaffected.
//!
//! Everything runs against the forged hermetic model — no artifacts,
//! no network beyond a loopback socket in the TCP leg.

use fourier_compress::codec::stream::StreamConfig;
use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::protocol::{ErrorCode, Frame};
use fourier_compress::coordinator::{span_id, start_service, DeviceClient,
                                    EdgeServer, FlightKind, CLIENT_CAPS};
use fourier_compress::model::tokenizer;
use fourier_compress::net::Channel;
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::testkit::forged_store;
use fourier_compress::util::json;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn serve_config(store_root: &std::path::Path, overrides: &[String])
    -> ServeConfig {
    let mut args = vec![
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store_root.display()),
        "session_ttl_s=60".to_string(),
    ];
    args.extend_from_slice(overrides);
    ServeConfig::load(None, &args).unwrap()
}

/// The real serving geometry (bucket, ks, kd) from the manifest.
fn manifest_geoms(store: &ArtifactStore) -> Vec<(u16, u16, u16)> {
    store.manifest.path("serving.buckets")
        .and_then(|b| b.as_obj())
        .expect("buckets")
        .iter()
        .map(|(bstr, bj)| (bstr.parse().unwrap(),
                           bj.usize_or("ks", 0) as u16,
                           bj.usize_or("kd", 0) as u16))
        .collect()
}

const PROMPT: &str = "Q probe alpha ? A";

/// Satellite pin: the Stats frame's counters — served over both the
/// in-proc and TCP transports, queried mid-run and after — reconcile
/// exactly with what the clients themselves accounted: requests,
/// tokens, the key/delta frame and wire-byte split (the server counts
/// headerless-framed bodies; the client counts full wire images, so
/// they differ by exactly `FRAME_OVERHEAD_BYTES` per frame), and
/// open/close connection parity once everything drains.
#[test]
fn stats_reconcile_with_client_accounting_inproc_and_tcp() {
    use fourier_compress::coordinator::protocol::FRAME_OVERHEAD_BYTES;

    let store = Arc::new(forged_store("obs_stats").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &["compute_units=1".into()]);
    let handle = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = handle.addr.to_string();

    // one spectral-stream client over TCP, one recompute client
    // in-proc — both against the same running service
    let mut tcp = DeviceClient::connect(&addr, &store, 41,
                                        Channel::unlimited()).unwrap();
    assert!(tcp.enable_stream(StreamConfig { keyframe_interval: 32,
                                             drift_threshold: 0.0 }));
    let mut inproc = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 42).unwrap();

    let steps = 4usize;
    let mut ctx_tcp = tokenizer::encode_prompt(PROMPT);
    let mut ctx_ip = tokenizer::encode_prompt(PROMPT);
    for step in 0..steps {
        let (t1, _) = tcp.step(&ctx_tcp).unwrap();
        ctx_tcp.push(t1);
        let (t2, _) = inproc.step(&ctx_ip).unwrap();
        ctx_ip.push(t2);
        if step == 1 {
            // mid-soak: GetStats must answer on both transports while
            // decode traffic is still in flight
            for stats in [tcp.server_stats().unwrap(),
                          inproc.server_stats().unwrap()] {
                let j = json::parse(&stats).expect("stats json");
                assert!(j.usize_or("requests", 0) >= 2 * (step + 1),
                        "mid-soak stats stale: {stats}");
                assert!(j.get("shards").is_some(), "sharded families \
                        missing mid-soak");
            }
        }
    }

    let cs = tcp.stats.clone();
    let ci = inproc.stats.clone();
    tcp.bye().unwrap();
    inproc.bye().unwrap();
    drop(tcp);
    drop(inproc);

    // every connection we opened must retire on its own (Bye +
    // disconnect), restoring open/close parity
    let m = handle.metrics.clone();
    let t0 = Instant::now();
    while m.conns_opened.load(Ordering::Relaxed)
        != m.conns_closed.load(Ordering::Relaxed) {
        assert!(t0.elapsed() < Duration::from_secs(10),
                "connections never drained: {} opened, {} closed",
                m.conns_opened.load(Ordering::Relaxed),
                m.conns_closed.load(Ordering::Relaxed));
        std::thread::sleep(Duration::from_millis(10));
    }

    // token/request parity: both clients ran clean (no resyncs, no
    // rejects), so the server saw exactly their steps
    let want = (cs.requests + ci.requests) as usize;
    assert_eq!(want, 2 * steps);
    assert_eq!(m.requests.load(Ordering::Relaxed), want as u64);
    assert_eq!(m.tokens.load(Ordering::Relaxed), want as u64);
    assert_eq!(m.stream_rejects.load(Ordering::Relaxed), 0);
    assert_eq!(cs.resyncs, 0);

    // stream wire split: the server books body + stream header per
    // frame; the client's new key/delta byte counters book the full
    // wire image — off by exactly the frame overhead per frame
    assert_eq!(m.key_frames.load(Ordering::Relaxed), cs.key_frames);
    assert_eq!(m.delta_frames.load(Ordering::Relaxed), cs.delta_frames);
    assert!(cs.key_frames >= 1 && cs.delta_frames >= 1,
            "soak must exercise both frame kinds");
    assert_eq!(cs.key_bytes,
               m.key_bytes_rx.load(Ordering::Relaxed)
               + cs.key_frames * FRAME_OVERHEAD_BYTES as u64);
    assert_eq!(cs.delta_bytes,
               m.delta_bytes_rx.load(Ordering::Relaxed)
               + cs.delta_frames * FRAME_OVERHEAD_BYTES as u64);
    assert!(cs.key_bytes + cs.delta_bytes < cs.bytes_sent,
            "handshake/stats bytes sit outside the stream split");

    handle.shutdown();
}

/// Tentpole pin: with 1-in-1 sampling every step produces a completed
/// trace whose span matches what the *client* minted from the same
/// (session, request) pair — no wire change — with sane stage
/// timings; flipping to 1-in-3 sampling traces exactly the steps the
/// client-side predictor says it will.
#[test]
fn per_step_traces_match_client_predicted_spans() {
    let store = Arc::new(forged_store("obs_trace").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[
        "compute_units=1".into(),
        "trace_sample=1".into(),
    ]);
    let handle = start_service(&cfg, store.clone()).unwrap();
    let session = 7u64;
    let mut client = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, session).unwrap();

    let context = tokenizer::encode_prompt(PROMPT);
    let mut expected = Vec::new();
    for _ in 0..5 {
        client.step(&context).unwrap();
        assert_ne!(client.last_span(), 0);
        expected.push(client.last_span());
    }

    // the tx stamp lands just after the token reaches the client —
    // give the poll worker a beat to retire the last trace
    let t0 = Instant::now();
    while handle.traces().len() < 5 {
        assert!(t0.elapsed() < Duration::from_secs(5),
                "only {} of 5 traces completed", handle.traces().len());
        std::thread::sleep(Duration::from_millis(5));
    }
    let traces = handle.traces();
    assert_eq!(traces.len(), 5);
    for (i, t) in traces.iter().enumerate() {
        assert_eq!(t.span, expected[i], "server span != client span");
        assert_eq!(t.session, session);
        assert_eq!(t.request, i as u64 + 1);
        assert_eq!(t.span, span_id(t.session, t.request));
        assert_eq!(t.shard, handle.service().shard_of(session));
        assert!(t.bucket >= context.len(), "bucket fits the context");
        assert!(t.total_us >= t.exec_us, "total {} < exec {}",
                t.total_us, t.exec_us);
        assert!(t.total_us >= t.decompress_us + t.queue_wait_us,
                "stage sum exceeds residency");
    }

    // 1-in-3: the server must trace exactly the steps the shared
    // predictor samples — the client can tell, per step, whether the
    // server recorded it
    handle.obs().tracer.set_sample(3);
    let mut predicted = Vec::new();
    for _ in 0..30 {
        client.step(&context).unwrap();
        let span = client.last_span();
        if span % 3 == 0 {
            predicted.push(span);
        }
    }
    let t0 = Instant::now();
    loop {
        let got: Vec<u64> = handle.traces().iter()
            .filter(|t| t.request > 5)
            .map(|t| t.span)
            .collect();
        if got.len() >= predicted.len() {
            assert_eq!(got, predicted,
                       "sampled spans diverge from the client predictor");
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5),
                "sampled {} of {} predicted traces", got.len(),
                predicted.len());
        std::thread::sleep(Duration::from_millis(5));
    }

    client.bye().unwrap();
    handle.shutdown();
}

/// Acceptance pin: a poisoned delta frame (no keyframe ever seeded
/// the stream) must be fully diagnosable from the flight dump alone —
/// the dump names the session, its shard, and the offending sequence
/// number without any log scraping.
#[test]
fn poisoned_delta_is_diagnosable_from_flight_dump() {
    let store = Arc::new(forged_store("obs_poison").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &["compute_units=1".into()]);
    let handle = start_service(&cfg, store.clone()).unwrap();
    let session = 777_001u64;
    let (bucket, ks, kd) = manifest_geoms(&store)[0];

    // raw frames, no DeviceClient: the client-side resync machinery
    // would mask the reject we are injecting
    let (mut tx, mut rx) = {
        use fourier_compress::coordinator::Transport;
        (Box::new(handle.connect_inproc()) as Box<dyn Transport>).split()
            .unwrap()
    };
    tx.send(&Frame::hello(session, CLIENT_CAPS, "forge-tiny")).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::HelloAck { .. }));
    tx.send(&Frame::Delta {
        session, request: 1, seq: 7, keyframe: false, bucket,
        true_len: 4, ks, kd, point: 0, packed: vec![],
        updates: vec![(0, 1.0)],
        coded: vec![],
    }).unwrap();
    match rx.recv().unwrap() {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::StreamReject),
        other => panic!("poisoned delta answered {}", other.type_id()),
    }

    let dump = handle.dump_flight();
    let reject = dump.iter()
        .find(|e| e.kind == FlightKind::StreamReject)
        .unwrap_or_else(|| panic!("no stream_reject in flight dump: {dump:?}"));
    assert_eq!(reject.session, session);
    assert_eq!(reject.seq, 7);
    assert_eq!(reject.shard as usize, handle.service().shard_of(session));
    assert_eq!(handle.metrics.stream_rejects.load(Ordering::Relaxed), 1);

    drop(tx);
    drop(rx);
    handle.shutdown();
}

/// Tentpole pin: the snapshot timeline emits one delta-metrics JSONL
/// line per tick (plus a final line at shutdown), schema-stable, with
/// monotone timestamps, and the per-tick token deltas sum back to the
/// service's total token counter.
#[test]
fn snapshot_timeline_has_schema_and_monotone_time() {
    let store = Arc::new(forged_store("obs_snap").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[
        "compute_units=1".into(),
        "snapshot_interval_ms=20".into(),
    ]);
    let handle = start_service(&cfg, store.clone()).unwrap();
    let mut client = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 1).unwrap();

    let context = tokenizer::encode_prompt(PROMPT);
    for _ in 0..5 {
        client.step(&context).unwrap();
        std::thread::sleep(Duration::from_millis(15));
    }
    client.bye().unwrap();

    // keep the bundle alive past shutdown so the final stop-line is
    // included in what we check
    let obs = handle.obs().clone();
    let metrics = handle.metrics.clone();
    handle.shutdown();

    let lines = obs.snapshots();
    assert!(lines.len() >= 2, "expected several ticks, got {lines:?}");
    let mut last_t = 0.0f64;
    let mut token_sum = 0u64;
    for line in &lines {
        let j = json::parse(line)
            .unwrap_or_else(|e| panic!("bad snapshot line {line:?}: {e:?}"));
        for key in ["t_ms", "tokens", "requests", "batches", "bytes_rx",
                    "bytes_tx", "stream_rejects", "queued", "conns",
                    "sessions"] {
            assert!(j.get(key).is_some(), "snapshot missing {key}: {line}");
        }
        let t = j.f64_or("t_ms", -1.0);
        assert!(t >= last_t, "t_ms not monotone: {lines:?}");
        last_t = t;
        token_sum += j.usize_or("tokens", 0) as u64;
    }
    assert_eq!(token_sum, metrics.tokens.load(Ordering::Relaxed),
               "per-tick token deltas must sum to the counter");
}

/// Satellite pin (poll-loop health): with two poll workers, one hung
/// peer costs failed readiness probes — both workers keep visiting,
/// the active session's steps stay fast, and the dry-pass naps are
/// counted rather than burned as spin; the hung peer's eventual idle
/// disconnect lands in the flight recorder.
#[test]
fn hung_peer_leaves_other_workers_occupancy_unaffected() {
    let store = Arc::new(forged_store("obs_hung").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[
        "compute_units=1".into(),
        "poll_workers=2".into(),
        "idle_deadline_ms=200".into(),
    ]);
    let handle = start_service(&cfg, store.clone()).unwrap();

    let silent = handle.connect_inproc();
    let mut client = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 1).unwrap();
    let context = tokenizer::encode_prompt(PROMPT);
    let mut worst = Duration::ZERO;
    for _ in 0..6 {
        let t0 = Instant::now();
        client.step(&context).unwrap();
        worst = worst.max(t0.elapsed());
    }
    assert!(worst < Duration::from_secs(5),
            "a silent peer stalled an active session: worst {worst:?}");

    let t0 = Instant::now();
    while handle.metrics.idle_disconnects.load(Ordering::Relaxed) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10),
                "idle deadline never fired");
        client.step(&context).unwrap();
        std::thread::sleep(Duration::from_millis(50));
    }

    let obs = handle.obs();
    assert_eq!(obs.workers.len(), 2);
    for (wid, w) in obs.workers.iter().enumerate() {
        // the queue rotates through both workers: a hung peer parked
        // on one of them would zero the other's progress — or its own
        assert!(w.visits.load(Ordering::Relaxed) > 0,
                "worker {wid} made no visits");
    }
    let frames: u64 = obs.workers.iter()
        .map(|w| w.frames.load(Ordering::Relaxed)).sum();
    assert!(frames >= 8, "workers handled {frames} frames");
    let naps: u64 = obs.workers.iter()
        .map(|w| w.naps.load(Ordering::Relaxed)).sum();
    assert!(naps > 0, "idle time must be napped, not spun");
    assert!(handle.dump_flight().iter()
            .any(|e| e.kind == FlightKind::IdleDisconnect),
            "idle disconnect missing from flight dump");

    drop(silent);
    client.bye().unwrap();
    handle.shutdown();
}

/// The Stats JSON keeps every legacy flat key and gains the sharded
/// families sized to the service's actual topology.
#[test]
fn stats_json_exposes_sharded_families() {
    let store = Arc::new(forged_store("obs_shape").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[
        "compute_units=1".into(),
        "shards=4".into(),
        "poll_workers=3".into(),
    ]);
    let handle = start_service(&cfg, store.clone()).unwrap();
    let mut client = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 11).unwrap();
    let context = tokenizer::encode_prompt(PROMPT);
    let steps = 3usize;
    for _ in 0..steps {
        client.step(&context).unwrap();
    }

    let j = json::parse(&client.server_stats().unwrap()).unwrap();
    // legacy flat keys survive unchanged
    assert_eq!(j.usize_or("requests", 0), steps);
    assert_eq!(j.usize_or("tokens", 0), steps);
    assert!(j.path("e2e_us.count").is_some());
    // sharded families mirror the configured topology
    let shards = j.get("shards").and_then(|v| v.as_arr()).expect("shards");
    assert_eq!(shards.len(), 4);
    let admitted: usize = shards.iter()
        .map(|s| s.usize_or("admitted", 0)).sum();
    assert!(admitted >= 1, "our session was admitted somewhere");
    let workers = j.get("workers").and_then(|v| v.as_arr()).expect("workers");
    assert_eq!(workers.len(), 3);
    let buckets = j.get("buckets").and_then(|v| v.as_arr()).expect("buckets");
    let mut want: Vec<usize> = manifest_geoms(&store).iter()
        .map(|&(b, _, _)| b as usize).collect();
    want.sort_unstable();
    let got: Vec<usize> = buckets.iter()
        .map(|b| b.usize_or("bucket", 0)).collect();
    assert_eq!(got, want, "bucket families mirror the manifest");
    let enqueued: usize = buckets.iter()
        .map(|b| b.usize_or("enqueued", 0)).sum();
    assert_eq!(enqueued, steps, "every step passed through a bucket queue");
    assert!(j.usize_or("sessions", 0) >= 1, "live session gauge");

    client.bye().unwrap();
    handle.shutdown();
}
