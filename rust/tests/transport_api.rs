//! Serving API v2 integration tests: the transport abstraction (TCP
//! / in-proc / shaped), the negotiated handshake (version +
//! capability bits + bucket geometry), typed error codes, and
//! deterministic frame-drop stream resync — all hermetic against
//! testkit-forged artifacts, most of them without a single socket.

use fourier_compress::codec::rate::RateConfig;
use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::protocol::{caps, ErrorCode, Frame,
                                              ServerError, PROTOCOL_MAGIC,
                                              PROTOCOL_VERSION};
use fourier_compress::coordinator::{DeviceClient, EdgeServer, ShapedTransport,
                                    Transport, CLIENT_CAPS};
use fourier_compress::model::tokenizer;
use fourier_compress::net::{Channel, ChannelTrace, DropPlan};
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::testkit::{forged_store, forged_store_with, ForgeSpec};
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn serve_config(store_root: &std::path::Path, overrides: &[String])
    -> ServeConfig {
    let mut args = vec![
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store_root.display()),
    ];
    args.extend_from_slice(overrides);
    ServeConfig::load(None, &args).unwrap()
}

fn bucket16(store: &ArtifactStore) -> (u16, u16) {
    let b = store.manifest.path("serving.buckets.16").expect("bucket 16");
    (b.usize_or("ks", 0) as u16, b.usize_or("kd", 0) as u16)
}

/// The acceptance pin: a full serving body — two concurrent clients,
/// generation, stats, compression accounting — runs socket-free over
/// `InProcTransport`, and its token output is byte-identical to the
/// same prompts driven through the TCP adapter of the *same* server.
#[test]
fn full_serving_body_over_inproc_matches_tcp_twin() {
    let store = Arc::new(forged_store("tapi_twin").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[
        "max_batch=2".into(),
        "batch_deadline_us=500".into(),
    ]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();
    let prompts = ["Q mira hue ? A", "Q rok den ? A"];

    // TCP reference generations
    let mut tcp_tokens = Vec::new();
    for (i, prompt) in prompts.iter().enumerate() {
        let mut c = DeviceClient::connect(&addr, &store, 100 + i as u64,
                                          Channel::unlimited()).unwrap();
        let g = c.generate(prompt, 4).unwrap();
        assert!(g.steps >= 1);
        c.bye().unwrap();
        tcp_tokens.push(g.tokens);
    }

    // the same prompts, concurrently, with zero sockets
    let mut handles = Vec::new();
    for (i, prompt) in prompts.iter().enumerate() {
        let transport = server.connect_inproc();
        let store = store.clone();
        let prompt = prompt.to_string();
        handles.push(std::thread::spawn(move || {
            let mut c = DeviceClient::connect_over(Box::new(transport), &store,
                                                   200 + i as u64).unwrap();
            let g = c.generate(&prompt, 4).unwrap();
            assert!(c.stats.bytes_sent > 0);
            // byte accounting is medium-independent, so the
            // conjugate-symmetric packing win carries over unchanged
            assert!(c.stats.compression_ratio() > 4.0,
                    "ratio {}", c.stats.compression_ratio());
            let stats = c.server_stats().unwrap();
            assert!(stats.contains("\"requests\""));
            c.bye().unwrap();
            g.tokens
        }));
    }
    for (h, want) in handles.into_iter().zip(&tcp_tokens) {
        let got = h.join().unwrap();
        assert_eq!(&got, want, "in-proc tokens diverged from tcp twin");
    }
    assert!(server.metrics.requests.load(Ordering::Relaxed) >= 4);
    server.shutdown();
}

#[test]
fn version_and_magic_mismatch_are_typed_rejects() {
    let store = Arc::new(forged_store("tapi_ver").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();

    let (mut tx, mut rx) = Box::new(server.connect_inproc()).split().unwrap();
    // wrong protocol version
    tx.send(&Frame::Hello {
        magic: PROTOCOL_MAGIC, version: 99, caps: CLIENT_CAPS, session: 1,
        model: "m".into(),
    }).unwrap();
    match rx.recv().unwrap() {
        Frame::Error { code, msg } => {
            assert_eq!(code, ErrorCode::VersionMismatch);
            assert!(msg.contains("v99"), "msg: {msg}");
        }
        other => panic!("expected typed reject, got {}", other.type_id()),
    }
    // wrong magic (a v1 peer or garbage)
    tx.send(&Frame::Hello {
        magic: 0xDEAD_BEEF, version: PROTOCOL_VERSION, caps: CLIENT_CAPS,
        session: 1, model: "m".into(),
    }).unwrap();
    match rx.recv().unwrap() {
        Frame::Error { code, .. } => {
            assert_eq!(code, ErrorCode::VersionMismatch);
        }
        other => panic!("expected typed reject, got {}", other.type_id()),
    }
    // data before a successful handshake is an unknown-session reject
    tx.send(&Frame::Activation {
        session: 1, request: 1, bucket: 16, true_len: 4, ks: 1, kd: 1,
        point: 0, packed: vec![0.0],
        coded: vec![],
    }).unwrap();
    match rx.recv().unwrap() {
        Frame::Error { code, .. } => {
            assert_eq!(code, ErrorCode::UnknownSession);
        }
        other => panic!("expected unknown-session, got {}", other.type_id()),
    }
    assert_eq!(server.metrics.hellos.load(Ordering::Relaxed), 2);
    assert_eq!(server.metrics.proto_rejects.load(Ordering::Relaxed), 2);
    tx.send(&Frame::Bye).unwrap();
    server.shutdown();
}

/// Recompute-regime requests are stateless: the connection's own
/// session, TTL/LRU-evicted server-side, is transparently re-admitted
/// — a generation must survive an idle gap, exactly like the stream
/// regime survives it via keyframe resync.  But the handshake *binds*
/// the connection to its session: frames naming any other session are
/// a typed unknown-session reject, so one tenant can neither serve
/// through nor resurrect another's session id.
#[test]
fn recompute_requests_survive_session_eviction() {
    let store = Arc::new(forged_store("tapi_sess").expect("forge artifacts"));
    let (ks, kd) = bucket16(&store);
    let cfg = serve_config(&store.root, &["session_ttl_s=1".into()]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();

    let (mut tx, mut rx) = Box::new(server.connect_inproc()).split().unwrap();
    tx.send(&Frame::hello(7, CLIENT_CAPS, "forge-tiny")).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::HelloAck { .. }));
    let activation = |request: u64, session: u64| Frame::Activation {
        session, request, bucket: 16, true_len: 10, ks, kd, point: 0,
        packed: vec![0.25; ks as usize * kd as usize],
        coded: vec![],
    };
    tx.send(&activation(1, 7)).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 1, .. }));

    // idle past the TTL, then force eviction via another handshake
    // (eviction runs at admission time)
    std::thread::sleep(std::time::Duration::from_millis(1400));
    let (mut tx2, mut rx2) = Box::new(server.connect_inproc()).split().unwrap();
    tx2.send(&Frame::hello(8, CLIENT_CAPS, "forge-tiny")).unwrap();
    assert!(matches!(rx2.recv().unwrap(), Frame::HelloAck { .. }));

    // the evicted session's next recompute request must be served
    // (re-admitted), not failed mid-generation
    tx.send(&activation(2, 7)).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 2, .. }));
    // ...but a frame naming a session this connection did NOT
    // handshake is rejected — no cross-tenant serving or resurrection
    tx.send(&activation(3, 999)).unwrap();
    match rx.recv().unwrap() {
        Frame::Error { code, msg } => {
            assert_eq!(code, ErrorCode::UnknownSession, "{msg}");
            assert!(msg.contains("999"), "msg: {msg}");
        }
        other => panic!("expected unknown-session, got {}", other.type_id()),
    }
    tx.send(&Frame::Bye).unwrap();
    tx2.send(&Frame::Bye).unwrap();
    server.shutdown();
}

/// The scenario the versioned handshake exists for: a v1-era client
/// (old unversioned `Hello {session, model}` wire layout) must
/// receive a typed VersionMismatch reject frame, not a silent
/// disconnect on a parse failure.
#[test]
fn v1_wire_hello_gets_typed_version_reject() {
    use std::io::Write;
    let store = Arc::new(forged_store("tapi_v1").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();

    // hand-build the v1 frame: u32 body_len | u8 type=0
    //                          | u64 session | u16 model_len | model
    let model = b"llamette-m";
    let mut body = Vec::new();
    body.extend_from_slice(&9u64.to_le_bytes());
    body.extend_from_slice(&(model.len() as u16).to_le_bytes());
    body.extend_from_slice(model);
    let mut wire = (body.len() as u32).to_le_bytes().to_vec();
    wire.push(0);
    wire.extend_from_slice(&body);

    let mut tcp = std::net::TcpStream::connect(server.addr).unwrap();
    tcp.write_all(&wire).unwrap();
    tcp.flush().unwrap();
    match Frame::read_from(&mut tcp).unwrap() {
        Frame::Error { code, msg } => {
            assert_eq!(code, ErrorCode::VersionMismatch, "{msg}");
        }
        other => panic!("expected VersionMismatch, got {}", other.type_id()),
    }
    assert_eq!(server.metrics.proto_rejects.load(Ordering::Relaxed), 1);
    server.shutdown();
}

/// Capability downgrade: the client wants the stream, the server
/// does not advertise it → `enable_stream` reports the downgrade and
/// generation proceeds in the recompute regime, no errors anywhere.
#[test]
fn stream_capability_downgrade_falls_back_to_recompute() {
    let store = Arc::new(forged_store("tapi_caps").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &["stream=false".into()]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();

    let mut client = DeviceClient::connect_over(
        Box::new(server.connect_inproc()), &store, 31).unwrap();
    assert_eq!(client.server_caps() & caps::STREAM, 0);
    assert_ne!(client.server_caps() & caps::CODEC_FC, 0);
    assert!(!client.enable_stream(Default::default()),
            "enable_stream must report the downgrade");
    assert!(!client.stream_enabled());

    let g = client.generate("Q mira hue ? A", 3).unwrap();
    assert!(g.steps >= 1, "recompute fallback must still generate");
    assert_eq!(client.stats.key_frames + client.stats.delta_frames, 0,
               "no stream frames may leave a downgraded client");
    assert_eq!(client.stats.requests as usize, g.steps);
    client.bye().unwrap();

    let m = &server.metrics;
    assert_eq!(m.key_frames.load(Ordering::Relaxed), 0);
    assert_eq!(m.delta_frames.load(Ordering::Relaxed), 0);
    // ...and a rogue Delta from a non-negotiated peer is a typed reject
    let (mut tx, mut rx) = Box::new(server.connect_inproc()).split().unwrap();
    tx.send(&Frame::hello(32, CLIENT_CAPS, "forge-tiny")).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::HelloAck { .. }));
    let (ks, kd) = bucket16(&store);
    tx.send(&Frame::Delta {
        session: 32, request: 1, seq: 0, keyframe: true, bucket: 16,
        true_len: 10, ks, kd, point: 0,
        packed: vec![0.1; ks as usize * kd as usize],
        updates: vec![],
        coded: vec![],
    }).unwrap();
    match rx.recv().unwrap() {
        Frame::Error { code, msg } => {
            assert_eq!(code, ErrorCode::BadRequest);
            assert!(msg.contains("capability"), "msg: {msg}");
        }
        other => panic!("expected typed reject, got {}", other.type_id()),
    }
    tx.send(&Frame::Bye).unwrap();
    server.shutdown();
}

/// The HelloAck's advertised bucket geometry must agree with the
/// manifest both sides loaded — the negotiation closes the "client
/// assumes its manifest matches" hole, so this pin is the contract.
#[test]
fn helloack_bucket_geometry_agrees_with_manifest() {
    let store = Arc::new(forged_store("tapi_geom").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();

    let client = DeviceClient::connect_over(
        Box::new(server.connect_inproc()), &store, 41).unwrap();
    assert_eq!(client.negotiated_caps() & caps::STREAM, caps::STREAM);
    assert_eq!(client.negotiated_caps() & caps::LADDER, caps::LADDER);
    let advertised = client.server_buckets();
    let bmap = store.manifest.path("serving.buckets")
        .and_then(|b| b.as_obj()).expect("manifest buckets");
    assert_eq!(advertised.len(), bmap.len());
    for (bstr, bj) in bmap {
        let bucket: u16 = bstr.parse().unwrap();
        let adv = advertised.iter().find(|g| g.bucket == bucket)
            .unwrap_or_else(|| panic!("bucket {bucket} not advertised"));
        let (aks, akd) = adv.primary();
        assert_eq!(aks as usize, bj.usize_or("ks", 0), "bucket {bucket}");
        assert_eq!(akd as usize, bj.usize_or("kd", 0), "bucket {bucket}");
        // the full quality ladder is advertised and matches the
        // manifest point for point, forged error bounds included
        let ladder = bj.get("ladder").and_then(|v| v.as_arr())
            .expect("manifest ladder");
        assert_eq!(adv.ladder.len(), ladder.len(), "bucket {bucket}");
        assert!(adv.ladder.len() > 1, "bucket {bucket}: single-point ladder");
        for (i, (le, mj)) in adv.ladder.iter().zip(ladder).enumerate() {
            assert_eq!(le.ks as usize, mj.usize_or("ks", 0),
                       "bucket {bucket} point {i}");
            assert_eq!(le.kd as usize, mj.usize_or("kd", 0),
                       "bucket {bucket} point {i}");
            let want = mj.f64_or("err_bound", -1.0);
            assert!((le.err_bound as f64 - want).abs() < 1e-6,
                    "bucket {bucket} point {i}: bound {} vs manifest {want}",
                    le.err_bound);
        }
    }
    server.shutdown();
}

/// Lose one delta frame "on the wire" via the shaped transport's
/// deterministic drop plan: the server must reject the next delta
/// with a typed StreamReject (sequence gap), and a keyframe must
/// resync the stream — the exact recovery path the DeviceClient
/// automates, pinned here frame by frame.
#[test]
fn shaped_frame_drop_forces_stream_reject_then_keyframe_recovers() {
    let store = Arc::new(forged_store("tapi_drop").expect("forge artifacts"));
    let (ks, kd) = bucket16(&store);
    let n = ks as usize * kd as usize;
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();

    // send index 2 (the first sparse delta) is lost after crossing
    let shaped = ShapedTransport::new(Box::new(server.connect_inproc()),
                                      Channel::unlimited(),
                                      DropPlan::at(&[2]));
    let (mut tx, mut rx) = Box::new(shaped).split().unwrap();
    let delta = |request: u64, seq: u32, keyframe: bool| Frame::Delta {
        session: 51, request, seq, keyframe, bucket: 16, true_len: 10,
        ks, kd, point: 0,
        packed: if keyframe { vec![0.5; n] } else { vec![] },
        updates: if keyframe { vec![] } else { vec![(0, 0.75)] },
        coded: vec![],
    };

    tx.send(&Frame::hello(51, CLIENT_CAPS, "forge-tiny")).unwrap(); // idx 0
    assert!(matches!(rx.recv().unwrap(), Frame::HelloAck { .. }));
    tx.send(&delta(1, 0, true)).unwrap(); // idx 1: keyframe, seq 0
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 1, .. }));
    tx.send(&delta(2, 1, false)).unwrap(); // idx 2: DROPPED on the wire
    tx.send(&delta(3, 2, false)).unwrap(); // idx 3: server sees a seq gap
    match rx.recv().unwrap() {
        Frame::Error { code, msg } => {
            assert_eq!(code, ErrorCode::StreamReject, "{msg}");
        }
        other => panic!("expected StreamReject, got {}", other.type_id()),
    }
    // keyframe resync carries the full block and any sequence number
    tx.send(&delta(4, 3, true)).unwrap(); // idx 4
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 4, .. }));
    // and the stream continues in-sequence
    tx.send(&delta(5, 4, false)).unwrap(); // idx 5
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 5, .. }));

    assert_eq!(server.metrics.stream_rejects.load(Ordering::Relaxed), 1);
    tx.send(&Frame::Bye).unwrap();
    server.shutdown();
}

/// The handshake binds connection↔session both ways: while its owner
/// connection is alive, a session cannot be re-Hello'd by another
/// connection (no decoder stomping, no caps rewriting); once the
/// owner disconnects, the id becomes re-bindable — the legitimate
/// reconnect path.
#[test]
fn live_session_cannot_be_taken_over_by_another_connection() {
    let store = Arc::new(forged_store("tapi_own").expect("forge artifacts"));
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();

    let (mut tx_a, mut rx_a) =
        Box::new(server.connect_inproc()).split().unwrap();
    tx_a.send(&Frame::hello(7, CLIENT_CAPS, "forge-tiny")).unwrap();
    assert!(matches!(rx_a.recv().unwrap(), Frame::HelloAck { .. }));

    // a second live connection may not bind the same session
    let (mut tx_b, mut rx_b) =
        Box::new(server.connect_inproc()).split().unwrap();
    tx_b.send(&Frame::hello(7, CLIENT_CAPS, "forge-tiny")).unwrap();
    match rx_b.recv().unwrap() {
        Frame::Error { code, msg } => {
            assert_eq!(code, ErrorCode::AdmissionRefused, "{msg}");
            assert!(msg.contains("bound"), "msg: {msg}");
        }
        other => panic!("expected takeover reject, got {}", other.type_id()),
    }

    // owner disconnects: the session becomes re-bindable (poll — the
    // connection thread releases ownership asynchronously after Bye)
    tx_a.send(&Frame::Bye).unwrap();
    drop(tx_a);
    drop(rx_a);
    let mut rebound = false;
    for _ in 0..250 {
        tx_b.send(&Frame::hello(7, CLIENT_CAPS, "forge-tiny")).unwrap();
        match rx_b.recv().unwrap() {
            Frame::HelloAck { .. } => {
                rebound = true;
                break;
            }
            Frame::Error { .. } => {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            other => panic!("unexpected frame {}", other.type_id()),
        }
    }
    assert!(rebound, "released session never became re-bindable");
    tx_b.send(&Frame::Bye).unwrap();
    server.shutdown();
}

/// Ladder-point rules, pinned frame by frame: the server validates a
/// data frame's point id + geometry against the ladder it advertised,
/// rejects un-advertised points, accepts a downshifted Activation
/// (embedding the nested block into the primary geometry), and in
/// stream mode allows a ladder switch only on a keyframe — a delta
/// naming a new point is a typed StreamReject, exactly like a
/// sequence gap.
#[test]
fn ladder_point_validation_and_switch_rules() {
    let store = Arc::new(forged_store("tapi_ladder").expect("forge"));
    let lj = store.manifest.path("serving.buckets.16")
        .and_then(|b| b.get("ladder"))
        .and_then(|l| l.as_arr())
        .expect("manifest ladder");
    assert!(lj.len() >= 2, "forged ladder must have >= 2 points");
    let point_geom = |i: usize| -> (u16, u16) {
        (lj[i].usize_or("ks", 0) as u16, lj[i].usize_or("kd", 0) as u16)
    };
    let (ks0, kd0) = point_geom(0);
    let (ks1, kd1) = point_geom(1);
    assert!((ks1 as usize) * (kd1 as usize) < (ks0 as usize) * (kd0 as usize),
            "point 1 must be cheaper than point 0");
    let cfg = serve_config(&store.root, &[]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();

    let (mut tx, mut rx) = Box::new(server.connect_inproc()).split().unwrap();
    tx.send(&Frame::hello(61, CLIENT_CAPS, "forge-tiny")).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::HelloAck { .. }));
    let expect_err = |rx: &mut Box<dyn fourier_compress::coordinator::FrameRx>,
                      want: ErrorCode| {
        match rx.recv().unwrap() {
            Frame::Error { code, msg } => assert_eq!(code, want, "{msg}"),
            other => panic!("expected {want:?}, got {}", other.type_id()),
        }
    };

    // unknown point id: typed reject
    tx.send(&Frame::Activation {
        session: 61, request: 1, bucket: 16, true_len: 10, ks: ks0, kd: kd0,
        point: 9, packed: vec![0.1; ks0 as usize * kd0 as usize],
        coded: vec![],
    }).unwrap();
    expect_err(&mut rx, ErrorCode::BadRequest);
    // point/geometry mismatch: point 1 with point-0 geometry
    tx.send(&Frame::Activation {
        session: 61, request: 2, bucket: 16, true_len: 10, ks: ks0, kd: kd0,
        point: 1, packed: vec![0.1; ks0 as usize * kd0 as usize],
        coded: vec![],
    }).unwrap();
    expect_err(&mut rx, ErrorCode::BadRequest);
    // valid downshifted activation: served (embedded into primary)
    tx.send(&Frame::Activation {
        session: 61, request: 3, bucket: 16, true_len: 10, ks: ks1, kd: kd1,
        point: 1, packed: vec![0.25; ks1 as usize * kd1 as usize],
        coded: vec![],
    }).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 3, .. }));

    // stream mode at point 1: keyframe admits, delta follows
    let delta = |request: u64, seq: u32, keyframe: bool, point: u8,
                 ks: u16, kd: u16| Frame::Delta {
        session: 61, request, seq, keyframe, bucket: 16, true_len: 10,
        ks, kd, point,
        packed: if keyframe { vec![0.5; ks as usize * kd as usize] }
                else { vec![] },
        updates: if keyframe { vec![] } else { vec![(0, 0.75)] },
        coded: vec![],
    };
    tx.send(&delta(4, 0, true, 1, ks1, kd1)).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 4, .. }));
    tx.send(&delta(5, 1, false, 1, ks1, kd1)).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 5, .. }));
    // an interleaved RECOMPUTE frame at another point must not poison
    // the stream: the next in-sequence delta at the stream's point is
    // still served (the stream geometry only moves on keyframes)
    tx.send(&Frame::Activation {
        session: 61, request: 50, bucket: 16, true_len: 10, ks: ks0, kd: kd0,
        point: 0, packed: vec![0.25; ks0 as usize * kd0 as usize],
        coded: vec![],
    }).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 50, .. }));
    tx.send(&delta(6, 2, false, 1, ks1, kd1)).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 6, .. }));
    // a ladder switch on a DELTA is refused: the geometry changed, so
    // it must arrive as a keyframe (the stream-resync lane)
    tx.send(&delta(7, 3, false, 0, ks0, kd0)).unwrap();
    expect_err(&mut rx, ErrorCode::StreamReject);
    // the switch via keyframe is clean
    tx.send(&delta(8, 4, true, 0, ks0, kd0)).unwrap();
    assert!(matches!(rx.recv().unwrap(), Frame::Token { request: 8, .. }));

    // dwell accounting: 0->1 (request 3), 1->0 (the interleaved
    // recompute), 0->1 (the delta riding the stream point), 1->0
    // (the switching keyframe) — the rejected frames never count
    assert_eq!(server.metrics.ladder_switches.load(Ordering::Relaxed), 4);
    tx.send(&Frame::Bye).unwrap();
    server.shutdown();
}

fn gen_steps(c: &mut DeviceClient, prompt: &str, steps: usize) -> Vec<i32> {
    let mut ctx = tokenizer::encode_prompt(prompt);
    let mut out = Vec::new();
    for _ in 0..steps {
        let (t, _) = c.step(&ctx).unwrap();
        ctx.push(t);
        out.push(t);
    }
    out
}

/// The adaptive soak (the tentpole's acceptance scenario): four
/// concurrent adaptive clients over a shaped link whose throttle
/// steps down ~700x mid-generation and then recovers.  Every session
/// must downshift its ladder point under the collapsed link, recover
/// the primary point on the fast tail, and still produce exactly the
/// recompute baseline's tokens — the forged ladders keep every point
/// inside the model's layer-1 band, so quality never moves, only
/// bytes do.
#[test]
fn adaptive_clients_downshift_and_recover_over_fluctuating_link() {
    let store = Arc::new(forged_store_with(
        "tapi_soak", &[ForgeSpec::tiny_adaptive()], "forge-adapt")
        .expect("forge"));
    let cfg = serve_config(&store.root, &[
        "max_batch=2".into(),
        "batch_deadline_us=500".into(),
    ]);
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    const STEPS: usize = 16;
    let prompts = ["Q rok ? A", "Q mira ? A", "Q zeb ? A", "Q kol ? A"];

    // recompute baselines: primary point, unshaped link
    let mut base = Vec::new();
    for (i, prompt) in prompts.iter().enumerate() {
        let mut c = DeviceClient::connect_over(
            Box::new(server.connect_inproc()), &store, 300 + i as u64)
            .unwrap();
        base.push(gen_steps(&mut c, prompt, STEPS));
        c.bye().unwrap();
    }

    // sends 0..=3 (hello + 3 steps) fast, 4..=9 collapsed, then fast
    let fast = Channel::gbps(0.05, 0); // 50 Mbit/s
    let slow = Channel::gbps(0.00005, 0); // 50 kbit/s
    let trace = ChannelTrace::new(&[(4, fast), (6, slow), (1, fast)]);
    let mut handles = Vec::new();
    for (i, prompt) in prompts.iter().enumerate() {
        let transport = ShapedTransport::with_trace(
            Box::new(server.connect_inproc()), trace.clone(),
            DropPlan::none());
        let store = store.clone();
        let prompt = prompt.to_string();
        handles.push(std::thread::spawn(move || {
            let mut c = DeviceClient::connect_over(Box::new(transport),
                                                   &store, 400 + i as u64)
                .unwrap();
            assert!(c.enable_adaptive(RateConfig {
                error_budget: 1.0,
                target_step_s: 0.025,
                ewma_alpha: 0.7,
                min_dwell_steps: 2,
                up_margin: 1.5,
            }), "handshake must negotiate the ladder capability");
            let toks = gen_steps(&mut c, &prompt, STEPS);
            assert!(c.stats.max_point > 0,
                    "session never downshifted under the collapsed link");
            assert_eq!(c.current_point(), 0,
                       "session never recovered the primary point");
            assert!(c.stats.ladder_switches >= 2,
                    "expected a down- and an up-switch, saw {}",
                    c.stats.ladder_switches);
            c.bye().unwrap();
            toks
        }));
    }
    for (h, want) in handles.into_iter().zip(&base) {
        let got = h.join().unwrap();
        assert_eq!(&got, want,
                   "adaptive ladder riding must not move a single token");
    }
    // the server recorded the dwell churn
    assert!(server.metrics.ladder_switches.load(Ordering::Relaxed) >= 8,
            "switches {}",
            server.metrics.ladder_switches.load(Ordering::Relaxed));
    server.shutdown();
}

#[test]
fn server_error_downcasts_from_anyhow() {
    let e: anyhow::Error = ServerError {
        code: ErrorCode::StreamReject,
        msg: "gap".into(),
    }.into();
    let se = e.downcast_ref::<ServerError>().expect("downcast");
    assert_eq!(se.code, ErrorCode::StreamReject);
    assert!(format!("{se}").contains("stream-reject"));
}
