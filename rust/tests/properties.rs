//! Seeded property suite (`util::rng`, no external fuzzer): hundreds
//! of random shapes and ladder points driven through the codec wire
//! transforms, the forged Parseval bounds, the stream encoder's drift
//! contract, and the rate controller's safety invariant — the four
//! properties the adaptive serving stack leans on.  Everything is
//! deterministic: a failure reproduces from its printed case index.

use fourier_compress::codec::fourier::{pack_block, pack_block_into,
                                       unpack_block, unpack_block_into,
                                       FourierCodec};
use fourier_compress::codec::quant::Int8Codec;
use fourier_compress::codec::rate::{validate_ladder, LadderPoint, RateConfig,
                                    RateController};
use fourier_compress::codec::stream::{fc_payload, split_prefill, BlockGeom,
                                      PrefillAssembler, PrefillConfig,
                                      StreamConfig, StreamDecoder,
                                      StreamEncoder, StreamStep};
use fourier_compress::codec::{rel_error, valid_block_axis, Codec,
                              CodecEngine, Payload};
use fourier_compress::tensor::MatView;
use fourier_compress::coordinator::protocol::Frame;
use fourier_compress::testkit::{band_limited_act, bucket_ladder, ForgeSpec};
use fourier_compress::util::rng::Rng;

/// A random valid centred block width for an `n`-point axis: odd and
/// <= n, occasionally the full axis.
fn rand_axis(rng: &mut Rng, n: usize) -> usize {
    if rng.below(8) == 0 {
        return n;
    }
    let k = 2 * rng.below(n.div_ceil(2)) + 1;
    if k > n { n } else { k }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Property: for random geometries, the conjugate-symmetric wire
/// transform round-trips bit-exactly — unpack(pack) of a block
/// derived from real data, and pack(unpack) of *arbitrary* packed
/// floats — and the fc codec is byte-deterministic at every point.
#[test]
fn pack_unpack_roundtrips_bit_exactly_over_random_geometries() {
    let mut rng = Rng::new(0x9E01);
    let codec = FourierCodec::default();
    for case in 0..300 {
        let rows = 1 + rng.below(40);
        let cols = 1 + rng.below(48);
        let ks = rand_axis(&mut rng, rows);
        let kd = rand_axis(&mut rng, cols);
        assert!(valid_block_axis(rows, ks) && valid_block_axis(cols, kd),
                "case {case}: generator produced invalid axis");

        // arbitrary packed floats: unpack -> pack must reproduce them
        // bit for bit (the mirror completion is exact, not lossy)
        let n = ks * kd;
        let packed: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let (re, im) = unpack_block(&packed, rows, cols, ks, kd)
            .unwrap_or_else(|e| panic!("case {case} ({rows}x{cols} block \
                                        {ks}x{kd}): {e}"));
        let back = pack_block(&re, &im, rows, cols, ks, kd);
        assert_eq!(bits(&back), bits(&packed),
                   "case {case}: pack(unpack) not bit-exact");

        // fc compression is byte-deterministic and self-consistent
        let a: Vec<f32> =
            (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let p1 = codec.compress_block(&a, rows, cols, ks, kd).unwrap();
        let p2 = codec.compress_block(&a, rows, cols, ks, kd).unwrap();
        assert_eq!(p1, p2, "case {case}: nondeterministic payload");
        let out = codec.decompress(&p1).unwrap();
        assert_eq!(out.len(), rows * cols);
        assert!(out.iter().all(|v| v.is_finite()), "case {case}");
    }
}

/// Property: ladder-bearing wire frames round-trip through
/// encode/decode exactly, for random header fields and bodies.
#[test]
fn ladder_frames_roundtrip_over_random_fields() {
    let mut rng = Rng::new(0x9E02);
    for case in 0..300 {
        let frame = if rng.below(2) == 0 {
            Frame::Activation {
                session: rng.next_u64(),
                request: rng.next_u64(),
                bucket: rng.below(1 << 16) as u16,
                true_len: rng.below(1 << 16) as u16,
                ks: rng.below(64) as u16,
                kd: rng.below(64) as u16,
                point: rng.below(8) as u8,
                packed: (0..rng.below(50))
                    .map(|_| rng.normal() as f32)
                    .collect(),
                coded: vec![],
            }
        } else {
            let keyframe = rng.below(2) == 0;
            Frame::Delta {
                session: rng.next_u64(),
                request: rng.next_u64(),
                seq: rng.next_u64() as u32,
                keyframe,
                bucket: rng.below(1 << 16) as u16,
                true_len: rng.below(1 << 16) as u16,
                ks: rng.below(64) as u16,
                kd: rng.below(64) as u16,
                point: rng.below(8) as u8,
                packed: if keyframe {
                    (0..rng.below(50)).map(|_| rng.normal() as f32).collect()
                } else {
                    vec![]
                },
                updates: if keyframe {
                    vec![]
                } else {
                    (0..rng.below(20))
                        .map(|_| (rng.next_u64() as u32,
                                  rng.normal() as f32))
                        .collect()
                },
                coded: vec![],
            }
        };
        let enc = frame.encode();
        let mut cur = std::io::Cursor::new(enc);
        let back = Frame::read_from(&mut cur).unwrap();
        assert_eq!(back, frame, "case {case}");
    }
}

/// Property: for every forged ladder point of every forged spec, the
/// *additional* FC reconstruction error the point introduces over the
/// bucket's primary block — measured on fresh band-limited
/// activations — respects the manifest's forged Parseval bound.  This
/// is the quantity the rate controller's error budget is written
/// against: what adaptivity may sacrifice relative to the paper's
/// fixed block.
#[test]
fn fc_error_respects_the_forged_parseval_bound() {
    let codec = FourierCodec::default();
    let mut rng = Rng::new(0x9E03);
    let mut checked = 0usize;
    for spec in [ForgeSpec::tiny(), ForgeSpec::tiny_adaptive()] {
        for &bucket in &spec.seq_buckets {
            let ladder = bucket_ladder(bucket, spec.d_model,
                                       spec.l1_freq_bins, &spec.ladder_kds,
                                       spec.ratio).unwrap();
            for _ in 0..30 {
                let a = band_limited_act(bucket, spec.d_model,
                                         spec.l1_freq_bins, rng.next_u64());
                let r0 = codec
                    .decompress(&codec.compress_block(&a, bucket,
                                                      spec.d_model,
                                                      ladder[0].ks,
                                                      ladder[0].kd).unwrap())
                    .unwrap();
                for p in &ladder {
                    let pay = codec.compress_block(&a, bucket, spec.d_model,
                                                   p.ks, p.kd).unwrap();
                    let rec = codec.decompress(&pay).unwrap();
                    let err = rel_error(&r0, &rec);
                    assert!(err <= p.err_bound + 1e-9,
                            "{} bucket {bucket} point {}x{}: extra err \
                             {err} > forged bound {}", spec.name, p.ks, p.kd,
                            p.err_bound);
                    checked += 1;
                }
            }
        }
    }
    assert!(checked >= 300, "only {checked} (point, sample) pairs checked");
}

/// Property: across random geometries, thresholds, and evolution
/// walks, the stream encoder's unsent drift never exceeds its
/// threshold — measured both through `last_drift` (what the rate
/// controller consumes) and through the actual reconstructions (what
/// the user sees).  This is the rate controller's safety invariant:
/// `err_bound + drift <= error_budget` is only a bound because drift
/// itself is bounded.
#[test]
fn stream_drift_never_exceeds_threshold() {
    let codec = FourierCodec::default();
    let mut rng = Rng::new(0x9E04);
    for case in 0..40 {
        let rows = 4 + rng.below(28);
        let cols = 4 + rng.below(28);
        let geom = BlockGeom {
            rows,
            cols,
            ks: rand_axis(&mut rng, rows),
            kd: rand_axis(&mut rng, cols),
        };
        let n = geom.ks * geom.kd;
        let thr = [0.0, 0.05, 0.2, 0.5][rng.below(4)];
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1 + rng.below(32) as u32,
            drift_threshold: thr,
        });
        let mut dec = StreamDecoder::new();
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let mut truth: Vec<f32> =
            (0..n).map(|_| rng.normal() as f32).collect();
        for step in 0..12 {
            if step > 0 {
                for _ in 0..1 + rng.below(4) {
                    let i = rng.below(n);
                    truth[i] += 0.5 * rng.normal() as f32;
                }
            }
            enc.encode_into(&mut eng, geom, &truth, &mut out).unwrap();
            assert!(enc.last_drift() <= thr + 1e-9,
                    "case {case} step {step}: last_drift {} > {thr}",
                    enc.last_drift());
            if out.keyframe {
                dec.apply_key(out.seq, geom, &out.packed).unwrap();
                assert_eq!(enc.last_drift(), 0.0);
            } else {
                dec.apply_delta(out.seq, geom, &out.updates).unwrap();
            }
            // decoder state reconstructs within the threshold of the
            // true block's reconstruction (Parseval)
            let want = codec.decompress(&fc_payload(geom, &truth)).unwrap();
            let got =
                codec.decompress(&fc_payload(geom, dec.block())).unwrap();
            let err = rel_error(&want, &got);
            assert!(err <= thr * 1.02 + 1e-6,
                    "case {case} step {step}: recon drift {err} > {thr}");
        }
    }
}

/// Property: across random geometries, chunk sizes, and drift
/// thresholds, chunked prefill splits into a well-formed sequence
/// (keyframe chunk 0, contiguous indices, exactly one `last`), the
/// server-side assembler reproduces the transmitted plane *bit
/// exactly*, a zero threshold is fully lossless, and the cumulative
/// drift every chunk of one prompt leaves unsent stays under the
/// advertised Parseval bound — measured on the reconstructions, the
/// quantity the bound is written against.
#[test]
fn prefill_split_reassemble_roundtrips_and_bounds_cumulative_drift() {
    let codec = FourierCodec::default();
    let mut eng = CodecEngine::new();
    let mut rng = Rng::new(0x9E07);
    let (mut chunks, mut state) = (Vec::new(), Vec::new());
    for case in 0..300 {
        let rows = 1 + rng.below(40);
        let cols = 1 + rng.below(48);
        let geom = BlockGeom {
            rows,
            cols,
            ks: rand_axis(&mut rng, rows),
            kd: rand_axis(&mut rng, cols),
        };
        let n = geom.ks * geom.kd;
        let packed: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        // chunk sizes from single-row up past the whole plane (the
        // degenerate single-chunk prefill)
        let chunk_rows = 1 + rng.below(geom.ks + 2);
        let thr = [0.0, 0.0, 0.01, 0.1][rng.below(4)];
        let cfg = PrefillConfig { chunk_rows, drift_threshold: thr };
        let drift = split_prefill(&mut eng, geom, &packed, cfg, &mut chunks,
                                  &mut state)
            .unwrap_or_else(|e| panic!("case {case} ({rows}x{cols} block \
                                        {}x{}): {e}", geom.ks, geom.kd));
        assert!(drift <= thr + 1e-9,
                "case {case}: reported drift {drift} > {thr}");

        // sequence shape: keyframe chunk 0, contiguous indices,
        // exactly the expected count, `last` only on the final chunk
        assert!(chunks[0].keyframe && chunks[0].index == 0, "case {case}");
        assert_eq!(chunks.len(),
                   n.div_ceil((chunk_rows * geom.kd).min(n)),
                   "case {case}: wrong chunk count");
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(c.index as usize, i, "case {case}");
            assert_eq!(c.last, i + 1 == chunks.len(), "case {case}");
        }

        // server-side reassembly is bit-exact against the encoder's
        // transmitted plane, and fully lossless at zero threshold
        let mut asm = PrefillAssembler::new();
        let mut done = None;
        for c in &chunks {
            let r = asm.apply(geom, c.index, c.last, c.keyframe, &c.packed,
                              &c.updates)
                .unwrap_or_else(|e| panic!("case {case} chunk {}: {e}",
                                           c.index));
            assert_eq!(r.is_some(), c.last, "case {case} chunk {}", c.index);
            if c.last {
                done = r;
            }
        }
        let plane = done.expect("last chunk completes the plane");
        assert_eq!(bits(&plane), bits(&state),
                   "case {case}: reassembly not bit-exact");
        if thr == 0.0 {
            assert_eq!(bits(&plane), bits(&packed),
                       "case {case}: zero threshold must be lossless");
        }

        // cumulative drift across every chunk of the prompt, measured
        // where it matters: between the reconstructions of the true
        // and the reassembled plane (Parseval)
        let want = codec.decompress(&fc_payload(geom, &packed)).unwrap();
        let got = codec.decompress(&fc_payload(geom, &plane)).unwrap();
        let err = rel_error(&want, &got);
        assert!(err <= thr * 1.02 + 1e-6,
                "case {case}: cumulative recon drift {err} > {thr}");
    }
}

/// Property: the vectorized kernel path and the scalar reference path
/// are *byte-identical* — same fc wire payloads, bit-equal
/// reconstructions, bit-equal pack/unpack planes, same int8 bytes —
/// over random geometries (radix-2 and Bluestein axis lengths alike).
/// This is the `simd` feature's parity contract: enabling it may only
/// change speed, never a single wire or output bit.  On a build
/// without the feature both engines dispatch the scalar path and the
/// test degenerates to a determinism check, so it is valid under
/// either feature configuration.
#[test]
fn simd_and_scalar_paths_are_byte_identical_over_random_geometries() {
    let codec = FourierCodec::default();
    let int8 = Int8Codec::default();
    let mut fast = CodecEngine::new(); // process-detected level
    let mut slow = CodecEngine::new();
    slow.set_simd_enabled(false);
    let mut rng = Rng::new(0x9E05);
    let (mut pf, mut ps) = (Payload::empty(), Payload::empty());
    let (mut of, mut os) = (Vec::new(), Vec::new());
    let (mut rf, mut xf) = (Vec::new(), Vec::new());
    let (mut rs, mut xs) = (Vec::new(), Vec::new());
    let (mut kf, mut ks_) = (Vec::new(), Vec::new());
    for case in 0..300 {
        let rows = 1 + rng.below(40);
        let cols = 1 + rng.below(48);
        let ks = rand_axis(&mut rng, rows);
        let kd = rand_axis(&mut rng, cols);
        let a: Vec<f32> =
            (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let view = MatView::new(&a, rows, cols);

        // fc: compressed wire bytes and reconstructed bits
        codec.compress_block_into(&mut fast, view, ks, kd, &mut pf).unwrap();
        codec.compress_block_into(&mut slow, view, ks, kd, &mut ps).unwrap();
        assert_eq!(pf, ps,
                   "case {case} ({rows}x{cols} block {ks}x{kd}): \
                    fc payload bytes diverge");
        codec.decompress_into(&mut fast, &pf, &mut of).unwrap();
        codec.decompress_into(&mut slow, &ps, &mut os).unwrap();
        assert_eq!(bits(&of), bits(&os),
                   "case {case}: fc reconstruction bits diverge");

        // wire transform: unpack then re-pack arbitrary packed floats
        let n = ks * kd;
        let packed: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        unpack_block_into(&mut fast, &packed, rows, cols, ks, kd, &mut rf,
                          &mut xf).unwrap();
        unpack_block_into(&mut slow, &packed, rows, cols, ks, kd, &mut rs,
                          &mut xs).unwrap();
        assert_eq!(bits(&rf), bits(&rs), "case {case}: unpack re diverges");
        assert_eq!(bits(&xf), bits(&xs), "case {case}: unpack im diverges");
        pack_block_into(&mut fast, &rf, &xf, rows, cols, ks, kd, &mut kf);
        pack_block_into(&mut slow, &rs, &xs, rows, cols, ks, kd, &mut ks_);
        assert_eq!(bits(&kf), bits(&ks_), "case {case}: pack diverges");

        // int8: quantized bytes and dequantized bits
        int8.compress_into(&mut fast, view, 4.0, &mut pf).unwrap();
        int8.compress_into(&mut slow, view, 4.0, &mut ps).unwrap();
        assert_eq!(pf, ps, "case {case}: int8 payload bytes diverge");
        int8.decompress_into(&mut fast, &pf, &mut of).unwrap();
        int8.decompress_into(&mut slow, &ps, &mut os).unwrap();
        assert_eq!(bits(&of), bits(&os),
                   "case {case}: int8 dequantized bits diverge");
    }
}

/// Property: the lossless entropy layer (`codec::wire`) round-trips
/// bit-exactly over random f32 planes, int8 planes, and update lists,
/// and never expands a body past raw + its plane header — the
/// try-and-compare guarantee the client's wire accounting and the
/// entropy bench's byte-win assertions lean on.  Covers the whole
/// sparsity/magnitude spectrum: smooth near-zero planes, white noise,
/// zero-run-heavy int8, and dense vs sparse index gaps.
#[test]
fn entropy_coding_roundtrips_bit_exactly_and_never_expands() {
    use fourier_compress::codec::wire::{self, PLANE_HEADER_BYTES};
    let mut rng = Rng::new(0x9E06);
    let mut coded = Vec::new();
    for case in 0..300 {
        coded.clear();
        match case % 3 {
            0 => {
                // f32 plane: random mix of exact zeros, normal noise,
                // and tiny smooth magnitudes (exponent clusters)
                let n = rng.below(400);
                let zero_p = rng.f64();
                let vals: Vec<f32> = (0..n)
                    .map(|_| {
                        if rng.f64() < zero_p {
                            0.0
                        } else if rng.below(2) == 0 {
                            rng.normal() as f32
                        } else {
                            (rng.f32() - 0.5) * 1e-3
                        }
                    })
                    .collect();
                wire::encode_f32_plane(&vals, &mut coded);
                assert!(coded.len() <= 4 * n + PLANE_HEADER_BYTES,
                        "case {case}: f32 plane expanded ({} > {})",
                        coded.len(), 4 * n + PLANE_HEADER_BYTES);
                let mut back = Vec::new();
                wire::decode_f32_plane(&coded, &mut back).unwrap();
                assert_eq!(bits(&back), bits(&vals),
                           "case {case}: f32 plane not bit-exact");
            }
            1 => {
                // i8 plane with random zero density and full range
                let n = rng.below(600);
                let zero_p = rng.f64();
                let vals: Vec<i8> = (0..n)
                    .map(|_| {
                        if rng.f64() < zero_p {
                            0
                        } else {
                            (rng.below(256) as i64 - 128) as i8
                        }
                    })
                    .collect();
                wire::encode_i8_plane(&vals, &mut coded);
                assert!(coded.len() <= n + PLANE_HEADER_BYTES,
                        "case {case}: i8 plane expanded");
                let mut back = Vec::new();
                wire::decode_i8_plane(&coded, &mut back).unwrap();
                assert_eq!(back, vals, "case {case}: i8 plane not exact");
            }
            _ => {
                // strictly-increasing update list with a random gap
                // scale (dense deltas and sparse scatters alike)
                let n = rng.below(200);
                let stride = 1 + rng.below(50);
                let mut idx = 0u32;
                let updates: Vec<(u32, f32)> = (0..n)
                    .map(|_| {
                        idx += 1 + rng.below(stride) as u32;
                        (idx, rng.normal() as f32)
                    })
                    .collect();
                wire::encode_updates(&updates, &mut coded);
                assert!(coded.len() <= 8 * n + PLANE_HEADER_BYTES,
                        "case {case}: update list expanded");
                let mut back = Vec::new();
                wire::decode_updates(&coded, &mut back).unwrap();
                // mode-1 lists decode index-sorted; the generator is
                // already strictly increasing, so equality is exact —
                // compared through bits so -0.0 cannot mask a flip
                let key = |u: &[(u32, f32)]| -> Vec<(u32, u32)> {
                    u.iter().map(|&(i, v)| (i, v.to_bits())).collect()
                };
                assert_eq!(key(&back), key(&updates),
                           "case {case}: update list not bit-exact");
            }
        }
    }
}

/// A random quality-monotone ladder (as `validate_ladder` demands).
fn rand_ladder(rng: &mut Rng) -> Vec<LadderPoint> {
    let len = 2 + rng.below(4);
    let mut ks = 9 + 2 * rng.below(12);
    let mut kd = 9 + 2 * rng.below(12);
    let mut bound = 0.02 + 0.1 * rng.f64();
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push(LadderPoint { ks, kd, err_bound: bound.min(1.0) });
        // even decrements keep the widths odd; floor at 1
        ks = ks.saturating_sub(2 * rng.below(3)).max(1);
        kd = kd.saturating_sub(2 * rng.below(3)).max(1);
        bound += 0.15 * rng.f64();
    }
    validate_ladder(&out).expect("generator must produce valid ladders");
    out
}

/// Property: under arbitrary observation streams the rate controller
/// (a) never rides a point whose bound + drift exceeds the budget
/// while an admissible point exists — its safety invariant — and
/// (b) never performs a non-emergency switch within the dwell floor,
/// and (c) is fully deterministic.
#[test]
fn rate_controller_safety_and_hysteresis_invariants() {
    for case in 0..60u64 {
        let mut rng = Rng::new(0xA000 + case);
        let ladder = rand_ladder(&mut rng);
        let cfg = RateConfig {
            error_budget: 0.2 + 0.8 * rng.f64(),
            target_step_s: 0.001 + 0.05 * rng.f64(),
            ewma_alpha: 0.2 + 0.7 * rng.f64(),
            min_dwell_steps: 1 + rng.below(5) as u32,
            up_margin: 1.0 + rng.f64(),
        };
        let mut a = RateController::new(ladder.clone(), cfg).unwrap();
        let mut b = RateController::new(ladder.clone(), cfg).unwrap();
        let mut drift = 0.0f64;
        let mut drift_ewma = 0.0f64;
        let mut last_point = a.point();
        let mut since_switch = u32::MAX;
        for step in 0..200 {
            // random link/drift weather
            if rng.below(3) == 0 {
                let bytes = 50 + rng.below(2000);
                let secs = 1e-5 + rng.f64() * 0.2;
                a.observe_send(bytes, secs);
                b.observe_send(bytes, secs);
            }
            if rng.below(4) == 0 {
                drift = rng.f64() * 0.6;
            }
            a.observe_drift(drift);
            b.observe_drift(drift);
            drift_ewma =
                cfg.ewma_alpha * drift + (1.0 - cfg.ewma_alpha) * drift_ewma;

            let before = a.point();
            let before_ok =
                ladder[before].err_bound + drift_ewma <= cfg.error_budget + 1e-9;
            let p = a.step();
            assert_eq!(p, b.step(), "case {case} step {step}: diverged");

            // (a) safety: if any point is admissible, the ridden one is
            let any_ok = ladder.iter().any(|q| {
                q.err_bound + drift_ewma <= cfg.error_budget + 1e-9
            });
            if any_ok {
                assert!(ladder[p].err_bound + drift_ewma
                            <= cfg.error_budget + 1e-6,
                        "case {case} step {step}: rode point {p} over \
                         budget while an admissible point existed");
            }

            // (b) hysteresis: a switch inside the dwell floor is only
            // legal as an emergency (the pre-switch point had fallen
            // out of budget)
            since_switch = since_switch.saturating_add(1);
            if p != last_point {
                assert!(since_switch >= cfg.min_dwell_steps || !before_ok,
                        "case {case} step {step}: non-emergency switch \
                         after {since_switch} < {} steps", cfg.min_dwell_steps);
                since_switch = 0;
                last_point = p;
            }
        }
    }
}
