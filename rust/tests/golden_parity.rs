//! Integration: rust runtime + native codecs replay the python-built
//! goldens — the end-to-end correctness contract between the three
//! layers.  Requires `make artifacts`; tests skip on a fresh tree.

use fourier_compress::codec::{fourier::FourierCodec, lowrank::SvdCodec,
                              topk::TopkCodec, Codec, rel_error};
use fourier_compress::model::executor::{Boundary, SplitExecutor};
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::tensor::io::read_fcw;

fn store() -> Option<ArtifactStore> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(ArtifactStore::open(root).expect("open artifacts"))
}

#[test]
fn codec_matches_python_reference() {
    let Some(store) = store() else { return };
    for model in store.model_names() {
        let meta = store.model_meta(&model).unwrap();
        let gpath = store.root.join(meta.str_or("golden", ""));
        let g = read_fcw(&gpath).unwrap();
        let a = &g["codec_a"];
        let (s, d) = (a.shape[0], a.shape[1]);
        let ks = g["ks_kd"].as_i32()[0] as usize;
        let kd = g["ks_kd"].as_i32()[1] as usize;

        // FC: reconstruction must match jnp's fft-based reference
        let fc = FourierCodec::default();
        let p = fc.compress_block(a.as_f32(), s, d, ks, kd).unwrap();
        let recon = fc.decompress(&p).unwrap();
        let err = rel_error(g["codec_recon"].as_f32(), &recon);
        assert!(err < 5e-4, "{model}: fc parity err {err}");

        // payload float count == ks*kd (conjugate-symmetric packing)
        assert_eq!((p.body.len() - 4) / 4, ks * kd, "{model}");

        // Top-k parity (k = n/16 as in the golden)
        let k = a.len() / 16;
        let tk = TopkCodec;
        let tp = tk.compress(a.as_f32(), s, d, (a.len() as f64) / (2.0 * k as f64))
            .unwrap();
        let trec = tk.decompress(&tp).unwrap();
        let terr = rel_error(g["topk_recon"].as_f32(), &trec);
        assert!(terr < 1e-5, "{model}: topk parity err {terr}");

        // SVD rank-4 parity (Jacobi vs LAPACK agree on the subspace)
        let sv = SvdCodec::plain();
        let rank4_ratio = (s * d) as f64 / (4 * (s + d)) as f64;
        let srec = sv.roundtrip(a.as_f32(), s, d, rank4_ratio).unwrap();
        let serr = rel_error(g["svd_r4_recon"].as_f32(), &srec);
        assert!(serr < 5e-3, "{model}: svd parity err {serr}");
    }
}

#[test]
fn split_pipeline_matches_python_logits() {
    let Some(store) = store() else { return };
    // full parity on one small model keeps the test under a minute;
    // codec parity above covers all four.
    let model = "llamette-s".to_string();
    let exec = SplitExecutor::new(&store, &model).unwrap();
    let g = read_fcw(store.root.join(&exec.meta.golden_path)).unwrap();

    let gt = &g["tokens"]; // [2, S]
    let (gb, s) = (gt.shape[0], gt.shape[1]);
    let b = exec.meta.eval_batch;
    assert_eq!(s, exec.meta.eval_seq);
    // tile golden rows up to the artifact batch
    let mut toks = Vec::with_capacity(b * s);
    for e in 0..b {
        let src = e % gb;
        toks.extend_from_slice(&gt.as_i32()[src * s..(src + 1) * s]);
    }
    let tokens = fourier_compress::tensor::Tensor::i32(vec![b, s], toks);
    let lens = vec![s; b];

    // uncompressed == python forward
    let (logits, _) = exec.forward_split(&tokens, &lens, 0, &Boundary::None).unwrap();
    let v = exec.meta.vocab_size;
    let want = g["logits_full"].as_f32();
    let got = &logits.as_f32()[..gb * s * v];
    let max = want.iter().zip(got).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max < 2e-2, "{model}: full-logit parity {max}");

    // split-1 + FC block == python split_forward
    let ks = g["ks_kd"].as_i32()[0] as usize;
    let kd = g["ks_kd"].as_i32()[1] as usize;
    let (logits2, ratio) = exec
        .forward_split(&tokens, &lens, 1, &Boundary::FcBlock { ks, kd })
        .unwrap();
    let want2 = g["logits_split1_fc8"].as_f32();
    let got2 = &logits2.as_f32()[..gb * s * v];
    let max2 = want2.iter().zip(got2).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max2 < 5e-2, "{model}: split-logit parity {max2}");
    assert!(ratio > 1.0);

    // layer-1 activation parity
    let acts = exec.activations(&tokens).unwrap();
    let a1 = &acts[0].as_f32()[..g["act_layer1"].len()];
    let wa = g["act_layer1"].as_f32();
    let amax = wa.iter().zip(a1).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(amax < 5e-3, "{model}: activation parity {amax}");
}

#[test]
fn hardware_codec_artifacts_execute() {
    let Some(store) = store() else { return };
    let entries = store.manifest.path("codec_hw.entries").unwrap().as_arr().unwrap();
    // smallest entry only (compile time); Table IV bench covers the rest
    let e = &entries[0];
    let (s, d) = (e.usize_or("seq", 0), e.usize_or("hidden", 0));
    let (ks, kd) = (e.usize_or("ks", 0), e.usize_or("kd", 0));
    let comp = store.get(e.get("compress").unwrap().as_str().unwrap()).unwrap();
    let deco = store.get(e.get("decompress").unwrap().as_str().unwrap()).unwrap();

    let mut rng = fourier_compress::util::rng::Rng::new(1);
    let mut a = vec![0.0f32; s * d];
    rng.fill_normal_f32(&mut a, 1.0);
    let at = fourier_compress::tensor::Tensor::f32(vec![s, d], a.clone());
    let out = comp.run(&[at]).unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].shape, vec![ks, kd]);

    // parity with the native software codec's spectrum gather
    let fc = FourierCodec::default();
    let p = fc.compress_block(&a, s, d, ks, kd).unwrap();
    let native = fc.decompress(&p).unwrap();
    let rec = deco.run(&[out[0].clone(), out[1].clone()]).unwrap();
    let err = rel_error(&native, rec[0].as_f32());
    assert!(err < 1e-3, "hw/sw codec parity {err}");
}
