//! Serving-core scale bench: drive the sharded, event-driven service
//! (poll pool + BatchFeed + compute workers) with rungs of 128 / 1k /
//! 4k concurrent in-proc sessions, all pipelined through the
//! split-phase client (`step_send` / `step_recv`), and measure p50 /
//! p99 step latency plus aggregate tokens/sec per rung.
//!
//! Two hard assertions:
//!
//! * The scaling contract: between consecutive rungs, aggregate
//!   throughput must not degrade super-linearly with session count —
//!   `tput(hi) >= tput(lo) / (hi_sessions / lo_sessions)`.  A serving
//!   core whose per-step cost grows with the number of *registered*
//!   sessions (global lock, per-connection threads thrashing the
//!   scheduler) fails this immediately at the 4k rung.
//! * The observability cost contract: the rungs run with snapshots
//!   and 1-in-16 trace sampling ON; a separate best-of-N pair of runs
//!   at the first rung measures the throughput overhead vs the same
//!   rung with observability OFF, and asserts it stays under 3%.
//!
//! Writes BENCH_scale.json — per-rung latency/throughput plus the
//! rung's snapshot timeline and the measured `obs_overhead_pct` — for
//! the CI smoke step.
//!
//!     cargo bench --bench scale_bench            # 128 / 1024 / 4096
//!     cargo bench --bench scale_bench -- --smoke # CI-sized rungs

use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::{start_service, DeviceClient};
use fourier_compress::model::tokenizer;
use fourier_compress::testkit::forged_store;
use fourier_compress::util::json::{self, Json};
use std::sync::Arc;
use std::time::Instant;

const DRIVERS: usize = 32;
const STEPS: usize = 3;
const PROMPT: &str = "Q rok ? A";
/// 1-in-N trace sampling the observed rungs run under.
const TRACE_SAMPLE: u64 = 16;
/// Snapshot tick for the per-rung timeline.
const SNAPSHOT_MS: u64 = 50;
/// Throughput runs per side of the overhead comparison (best-of).
const OVERHEAD_RUNS: usize = 3;
/// The observability cost contract: <3% aggregate-throughput overhead
/// with snapshots + sampled tracing on.
const OVERHEAD_CEILING: f64 = 0.03;

struct Rung {
    sessions: usize,
    p50_ms: f64,
    p99_ms: f64,
    tokens_per_sec: f64,
    /// Snapshot-timeline JSONL lines (observed runs only).
    timeline: Vec<String>,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Every timeline line must parse and carry the full delta-metrics
/// schema — a field silently dropped from the snapshot thread would
/// otherwise only surface when a dashboard breaks.
fn check_timeline_schema(timeline: &[String]) {
    let mut last_t = 0.0f64;
    for line in timeline {
        let j = json::parse(line)
            .unwrap_or_else(|e| panic!("bad snapshot line {line:?}: {e:?}"));
        for key in ["t_ms", "tokens", "requests", "batches", "bytes_rx",
                    "bytes_tx", "stream_rejects", "queued", "conns",
                    "sessions"] {
            assert!(j.get(key).is_some(), "snapshot missing {key}: {line}");
        }
        let t = j.f64_or("t_ms", -1.0);
        assert!(t >= last_t, "snapshot t_ms not monotone");
        last_t = t;
    }
}

fn run_rung(store: &Arc<fourier_compress::runtime::ArtifactStore>,
            sessions: usize, observe: bool) -> Rung {
    let mut args = vec![
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
        "max_batch=16".into(),
        "batch_deadline_us=200".into(),
        "compute_units=2".into(),
        "shards=8".into(),
        "poll_workers=4".into(),
        "idle_deadline_ms=0".into(),
    ];
    if observe {
        args.push(format!("snapshot_interval_ms={SNAPSHOT_MS}"));
        args.push(format!("trace_sample={TRACE_SAMPLE}"));
    }
    let cfg = ServeConfig::load(None, &args).unwrap();
    let handle = start_service(&cfg, store.clone()).expect("service");

    let per_driver = sessions / DRIVERS;
    assert!(per_driver >= 1, "rung {sessions} smaller than driver pool");

    // connect everything first: the rung measures steady-state decode
    // with all `sessions` connections registered with the poll pool
    let t_all = Instant::now();
    let lat_chunks: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for d in 0..DRIVERS {
            let handle = &handle;
            joins.push(scope.spawn(move || {
                let mut clients: Vec<(DeviceClient, Vec<i32>)> =
                    (0..per_driver)
                        .map(|i| {
                            let sid = 1 + (d * per_driver + i) as u64;
                            let c = DeviceClient::connect_over(
                                Box::new(handle.connect_inproc()),
                                store, sid)
                                .expect("connect");
                            (c, tokenizer::encode_prompt(PROMPT))
                        })
                        .collect();
                let mut lats = Vec::with_capacity(per_driver * STEPS);
                for _ in 0..STEPS {
                    let mut inflight = Vec::with_capacity(per_driver);
                    for (c, ctx) in clients.iter_mut() {
                        let t0 = Instant::now();
                        let req = c.step_send(&ctx[..]).expect("step_send");
                        inflight.push((req, t0));
                    }
                    for (slot, (req, t0)) in inflight.into_iter().enumerate() {
                        let (c, ctx) = &mut clients[slot];
                        let (token, _) = c.step_recv(req).expect("step_recv");
                        lats.push(t0.elapsed().as_secs_f64() * 1e3);
                        ctx.push(token);
                    }
                }
                for (mut c, _) in clients {
                    c.bye().expect("bye");
                }
                lats
            }));
        }
        joins.into_iter().map(|j| j.join().expect("driver")).collect()
    });
    let wall_s = t_all.elapsed().as_secs_f64();
    let obs = handle.obs().clone();
    handle.shutdown();
    // shutdown flushed the final snapshot line; an observed rung
    // always has a timeline, however short the run
    let timeline = if observe { obs.snapshots() } else { Vec::new() };
    if observe {
        assert!(!timeline.is_empty(), "observed rung produced no timeline");
        check_timeline_schema(&timeline);
    }

    let mut lats: Vec<f64> = lat_chunks.into_iter().flatten().collect();
    assert_eq!(lats.len(), per_driver * DRIVERS * STEPS);
    lats.sort_by(|a, b| a.total_cmp(b));
    Rung {
        sessions: per_driver * DRIVERS,
        p50_ms: percentile(&lats, 0.50),
        p99_ms: percentile(&lats, 0.99),
        tokens_per_sec: lats.len() as f64 / wall_s,
        timeline,
    }
}

/// Best-of-N aggregate throughput at one rung size (noise control for
/// the overhead comparison: scheduler jitter hits the worst runs, the
/// best run of each side is the honest capability number).
fn best_tput(store: &Arc<fourier_compress::runtime::ArtifactStore>,
             sessions: usize, observe: bool) -> f64 {
    (0..OVERHEAD_RUNS)
        .map(|_| run_rung(store, sessions, observe).tokens_per_sec)
        .fold(0.0f64, f64::max)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let rungs: &[usize] = if smoke { &[128, 512] } else { &[128, 1024, 4096] };

    let store = Arc::new(forged_store("scale_bench").expect("forge artifacts"));
    let mut results = Vec::new();
    for &n in rungs {
        let r = run_rung(&store, n, true);
        println!("{:>5} sessions: p50 {:.3} ms  p99 {:.3} ms  {:.0} tok/s  \
                  ({} timeline ticks)",
                 r.sessions, r.p50_ms, r.p99_ms, r.tokens_per_sec,
                 r.timeline.len());
        results.push(r);
    }

    // the scaling contract: growing the session count by Gx may cost
    // at most Gx in aggregate throughput (i.e. per-session throughput
    // degrades at worst linearly — never super-linearly)
    for w in results.windows(2) {
        let (lo, hi) = (&w[0], &w[1]);
        let growth = hi.sessions as f64 / lo.sessions as f64;
        let floor = lo.tokens_per_sec / growth;
        assert!(hi.tokens_per_sec >= floor,
                "super-linear degradation: {} sessions at {:.0} tok/s, but \
                 {} sessions fell to {:.0} tok/s (floor {:.0})",
                lo.sessions, lo.tokens_per_sec, hi.sessions,
                hi.tokens_per_sec, floor);
    }

    // the observability cost contract, measured: identical rungs with
    // the layer off vs on (snapshots + 1-in-16 tracing), best-of-N
    // each; the on-side may cost at most 3% aggregate throughput
    let off = best_tput(&store, rungs[0], false);
    let on = best_tput(&store, rungs[0], true);
    let overhead = (1.0 - on / off).max(0.0);
    println!("observability overhead at {} sessions: {:.2}% \
              (off {off:.0} tok/s, on {on:.0} tok/s)",
             rungs[0], overhead * 100.0);
    assert!(overhead < OVERHEAD_CEILING,
            "observability overhead {:.2}% breaches the {:.0}% contract \
             (off {off:.0} tok/s, on {on:.0} tok/s)",
            overhead * 100.0, OVERHEAD_CEILING * 100.0);

    let mut out = Json::obj();
    out.set("smoke", Json::Bool(smoke));
    out.set("drivers", Json::Num(DRIVERS as f64));
    out.set("steps_per_session", Json::Num(STEPS as f64));
    out.set("trace_sample", Json::Num(TRACE_SAMPLE as f64));
    out.set("snapshot_interval_ms", Json::Num(SNAPSHOT_MS as f64));
    out.set("obs_overhead_pct", Json::Num(overhead * 100.0));
    out.set("rungs", Json::Arr(results.iter().map(|r| {
        let mut j = Json::obj();
        j.set("sessions", Json::Num(r.sessions as f64));
        j.set("p50_step_ms", Json::Num(r.p50_ms));
        j.set("p99_step_ms", Json::Num(r.p99_ms));
        j.set("tokens_per_sec", Json::Num(r.tokens_per_sec));
        j.set("timeline", Json::Arr(r.timeline.iter().map(|line| {
            json::parse(line).expect("validated above")
        }).collect()));
        j
    }).collect()));
    std::fs::write("BENCH_scale.json", out.to_string_pretty())
        .expect("write BENCH_scale.json");
    println!("wrote BENCH_scale.json");
}
