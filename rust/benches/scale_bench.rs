//! Serving-core scale bench: drive the sharded, event-driven service
//! (poll pool + BatchFeed + compute workers) with rungs of 128 / 1k /
//! 4k concurrent in-proc sessions, all pipelined through the
//! split-phase client (`step_send` / `step_recv`), and measure p50 /
//! p99 step latency plus aggregate tokens/sec per rung.
//!
//! The hard assertion is the scaling contract: between consecutive
//! rungs, aggregate throughput must not degrade super-linearly with
//! session count — `tput(hi) >= tput(lo) / (hi_sessions /
//! lo_sessions)`.  A serving core whose per-step cost grows with the
//! number of *registered* sessions (global lock, per-connection
//! threads thrashing the scheduler) fails this immediately at the 4k
//! rung.  Writes BENCH_scale.json for the CI smoke step.
//!
//!     cargo bench --bench scale_bench            # 128 / 1024 / 4096
//!     cargo bench --bench scale_bench -- --smoke # CI-sized rungs

use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::{start_service, DeviceClient};
use fourier_compress::model::tokenizer;
use fourier_compress::testkit::forged_store;
use fourier_compress::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

const DRIVERS: usize = 32;
const STEPS: usize = 3;
const PROMPT: &str = "Q rok ? A";

struct Rung {
    sessions: usize,
    p50_ms: f64,
    p99_ms: f64,
    tokens_per_sec: f64,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn run_rung(store: &Arc<fourier_compress::runtime::ArtifactStore>,
            sessions: usize) -> Rung {
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
        "max_batch=16".into(),
        "batch_deadline_us=200".into(),
        "compute_units=2".into(),
        "shards=8".into(),
        "poll_workers=4".into(),
        "idle_deadline_ms=0".into(),
    ]).unwrap();
    let handle = start_service(&cfg, store.clone()).expect("service");

    let per_driver = sessions / DRIVERS;
    assert!(per_driver >= 1, "rung {sessions} smaller than driver pool");

    // connect everything first: the rung measures steady-state decode
    // with all `sessions` connections registered with the poll pool
    let t_all = Instant::now();
    let lat_chunks: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for d in 0..DRIVERS {
            let handle = &handle;
            joins.push(scope.spawn(move || {
                let mut clients: Vec<(DeviceClient, Vec<i32>)> =
                    (0..per_driver)
                        .map(|i| {
                            let sid = 1 + (d * per_driver + i) as u64;
                            let c = DeviceClient::connect_over(
                                Box::new(handle.connect_inproc()),
                                store, sid)
                                .expect("connect");
                            (c, tokenizer::encode_prompt(PROMPT))
                        })
                        .collect();
                let mut lats = Vec::with_capacity(per_driver * STEPS);
                for _ in 0..STEPS {
                    let mut inflight = Vec::with_capacity(per_driver);
                    for (c, ctx) in clients.iter_mut() {
                        let t0 = Instant::now();
                        let req = c.step_send(&ctx[..]).expect("step_send");
                        inflight.push((req, t0));
                    }
                    for (slot, (req, t0)) in inflight.into_iter().enumerate() {
                        let (c, ctx) = &mut clients[slot];
                        let (token, _) = c.step_recv(req).expect("step_recv");
                        lats.push(t0.elapsed().as_secs_f64() * 1e3);
                        ctx.push(token);
                    }
                }
                for (mut c, _) in clients {
                    c.bye().expect("bye");
                }
                lats
            }));
        }
        joins.into_iter().map(|j| j.join().expect("driver")).collect()
    });
    let wall_s = t_all.elapsed().as_secs_f64();
    handle.shutdown();

    let mut lats: Vec<f64> = lat_chunks.into_iter().flatten().collect();
    assert_eq!(lats.len(), per_driver * DRIVERS * STEPS);
    lats.sort_by(|a, b| a.total_cmp(b));
    Rung {
        sessions: per_driver * DRIVERS,
        p50_ms: percentile(&lats, 0.50),
        p99_ms: percentile(&lats, 0.99),
        tokens_per_sec: lats.len() as f64 / wall_s,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let rungs: &[usize] = if smoke { &[128, 512] } else { &[128, 1024, 4096] };

    let store = Arc::new(forged_store("scale_bench").expect("forge artifacts"));
    let mut results = Vec::new();
    for &n in rungs {
        let r = run_rung(&store, n);
        println!("{:>5} sessions: p50 {:.3} ms  p99 {:.3} ms  {:.0} tok/s",
                 r.sessions, r.p50_ms, r.p99_ms, r.tokens_per_sec);
        results.push(r);
    }

    // the scaling contract: growing the session count by Gx may cost
    // at most Gx in aggregate throughput (i.e. per-session throughput
    // degrades at worst linearly — never super-linearly)
    for w in results.windows(2) {
        let (lo, hi) = (&w[0], &w[1]);
        let growth = hi.sessions as f64 / lo.sessions as f64;
        let floor = lo.tokens_per_sec / growth;
        assert!(hi.tokens_per_sec >= floor,
                "super-linear degradation: {} sessions at {:.0} tok/s, but \
                 {} sessions fell to {:.0} tok/s (floor {:.0})",
                lo.sessions, lo.tokens_per_sec, hi.sessions,
                hi.tokens_per_sec, floor);
    }

    let mut out = Json::obj();
    out.set("smoke", Json::Bool(smoke));
    out.set("drivers", Json::Num(DRIVERS as f64));
    out.set("steps_per_session", Json::Num(STEPS as f64));
    out.set("rungs", Json::Arr(results.iter().map(|r| {
        let mut j = Json::obj();
        j.set("sessions", Json::Num(r.sessions as f64));
        j.set("p50_step_ms", Json::Num(r.p50_ms));
        j.set("p99_step_ms", Json::Num(r.p99_ms));
        j.set("tokens_per_sec", Json::Num(r.tokens_per_sec));
        j
    }).collect()));
    std::fs::write("BENCH_scale.json", out.to_string_pretty())
        .expect("write BENCH_scale.json");
    println!("wrote BENCH_scale.json");
}
