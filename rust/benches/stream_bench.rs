//! Stream-vs-recompute wire-byte bench: 128 decode steps over an
//! evolving spectral block at a serving-like geometry, comparing the
//! cumulative uplink bytes of the recompute regime (a full Activation
//! frame per step) against the spectral delta stream (keyframes +
//! sparse coefficient deltas), plus the Fig-7 byte-model columns.
//! Writes BENCH_stream.json and hard-asserts the >= 5x saving so the
//! CI smoke step fails loudly if the stream regresses.
//!
//!     cargo bench --bench stream_bench

use fourier_compress::codec::stream::{BlockGeom, StreamConfig, StreamDecoder,
                                      StreamEncoder, StreamStep};
use fourier_compress::codec::CodecEngine;
use fourier_compress::config::SimConfig;
use fourier_compress::coordinator::protocol::Frame;
use fourier_compress::sim::{bytes_per_step, Arm};
use fourier_compress::util::bench::bench;
use fourier_compress::util::json::Json;
use fourier_compress::util::rng::Rng;
use std::time::Duration;

const STEPS: usize = 128;

fn main() {
    let geom = BlockGeom { rows: 64, cols: 128, ks: 33, kd: 15 };
    let n = geom.ks * geom.kd;
    let cfg = StreamConfig { keyframe_interval: 16, drift_threshold: 0.02 };

    let mut rng = Rng::new(0x5B);
    let mut truth: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut enc = StreamEncoder::new(cfg);
    let mut dec = StreamDecoder::new();
    let mut eng = CodecEngine::new();
    let mut step = StreamStep::default();

    let (mut recompute_bytes, mut stream_bytes) = (0u64, 0u64);
    let (mut keys, mut deltas, mut updates) = (0u64, 0u64, 0u64);
    for t in 0..STEPS as u64 {
        if t > 0 {
            // decode-step evolution: a few spectral coefficients move
            for _ in 0..4 {
                let i = rng.below(n);
                truth[i] += rng.normal() as f32;
            }
        }
        let recompute = Frame::Activation {
            session: 1, request: t + 1, bucket: geom.rows as u16,
            true_len: geom.rows as u16, ks: geom.ks as u16,
            kd: geom.kd as u16, point: 0, packed: truth.clone(),
            coded: vec![],
        };
        recompute_bytes += recompute.encode().len() as u64;

        enc.encode_into(&mut eng, geom, &truth, &mut step).unwrap();
        let frame = Frame::Delta {
            session: 1, request: t + 1, seq: step.seq, keyframe: step.keyframe,
            bucket: geom.rows as u16, true_len: geom.rows as u16,
            ks: geom.ks as u16, kd: geom.kd as u16, point: 0,
            packed: step.packed.clone(), updates: step.updates.clone(),
            coded: vec![],
        };
        stream_bytes += frame.encode().len() as u64;
        if step.keyframe {
            keys += 1;
            dec.apply_key(step.seq, geom, &step.packed).unwrap();
        } else {
            deltas += 1;
            updates += step.updates.len() as u64;
            dec.apply_delta(step.seq, geom, &step.updates).unwrap();
        }
    }
    // the stream is exact at the coefficients it sends: encoder and
    // decoder state must agree bit for bit at the end of the run
    assert_eq!(dec.block().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
               enc.state().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
               "encoder/decoder state diverged");

    let savings = recompute_bytes as f64 / stream_bytes as f64;
    println!("{STEPS} steps @ {}x{} block {}x{}: recompute {recompute_bytes} B, \
              stream {stream_bytes} B ({savings:.1}x, {keys} keys / {deltas} \
              deltas, {updates} updates)",
             geom.rows, geom.cols, geom.ks, geom.kd);
    assert!(savings >= 5.0, "stream saved only {savings:.1}x");

    // encoder hot path at the same geometry
    let enc_t = bench("stream encode 64x128 (delta)", 500,
                      Duration::from_secs(2), || {
        enc.encode_into(&mut eng, geom, &truth, &mut step).unwrap();
        std::hint::black_box(&step);
    });

    // the Fig-7 byte model for the same 128-step horizon
    let sim_cfg = SimConfig { output_tokens: STEPS, ..SimConfig::default() };
    let cum = |arm: Arm| -> f64 {
        (0..STEPS).map(|t| bytes_per_step(&sim_cfg, arm, t)).sum()
    };

    let mut out = Json::obj();
    out.set("steps", Json::Num(STEPS as f64));
    out.set("geometry", Json::Str(format!("{}x{} block {}x{}", geom.rows,
                                          geom.cols, geom.ks, geom.kd)));
    out.set("keyframe_interval", Json::Num(cfg.keyframe_interval as f64));
    out.set("drift_threshold", Json::Num(cfg.drift_threshold));
    out.set("recompute_bytes", Json::Num(recompute_bytes as f64));
    out.set("stream_bytes", Json::Num(stream_bytes as f64));
    out.set("savings_x", Json::Num(savings));
    out.set("key_frames", Json::Num(keys as f64));
    out.set("delta_frames", Json::Num(deltas as f64));
    out.set("delta_updates", Json::Num(updates as f64));
    out.set("encode_s", Json::Num(enc_t.median.as_secs_f64()));
    out.set("model_orig_bytes", Json::Num(cum(Arm::Original)));
    out.set("model_fc_bytes", Json::Num(cum(Arm::Fc)));
    out.set("model_fcs_bytes", Json::Num(cum(Arm::FcStream)));
    std::fs::write("BENCH_stream.json", out.to_string_pretty())
        .expect("write BENCH_stream.json");
    println!("wrote BENCH_stream.json");
}
