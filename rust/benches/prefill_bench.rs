//! Chunked-prefill bench: the prompt-phase bandwidth cliff, measured
//! two ways and hard-asserted so the CI smoke step fails loudly on a
//! regression.
//!
//! 1. End to end through the *real* serving core (long-context forged
//!    artifacts, in-proc transport): a ~1500-token prompt sent
//!    monolithically vs as chunked prefill — prompt-phase wire bytes
//!    (hard-asserted >= 2x smaller chunked), time-to-first-token, and
//!    bit-identical generated tokens across the whole run.
//! 2. Codec-level on the band-limited activation family at the same
//!    2048-bucket serving geometry: every chunk reassembled and
//!    checked bit-exact against the encoder's transmitted plane, with
//!    the same >= 2x wire-byte gate vs the monolithic keyframe.
//!
//! Writes BENCH_prefill.json.
//!
//!     cargo bench --bench prefill_bench

use fourier_compress::codec::stream::{split_prefill, BlockGeom,
                                      PrefillAssembler, PrefillConfig};
use fourier_compress::codec::fourier::FourierCodec;
use fourier_compress::codec::{Codec, CodecEngine};
use fourier_compress::config::{FromJson, ServeConfig, SimConfig};
use fourier_compress::coordinator::protocol::{Frame, PREFILL_HEADER_BYTES};
use fourier_compress::coordinator::{start_service, DeviceClient};
use fourier_compress::model::tokenizer;
use fourier_compress::sim::{prompt_bytes, Arm};
use fourier_compress::testkit::{band_limited_act, forged_longctx_store};
use fourier_compress::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

const STEPS: usize = 8;
const CHUNK_ROWS: usize = 16;
const DRIFT_THR: f64 = 0.01;

/// A multi-thousand-token prompt that buckets to the long-context
/// store's 2048-token bucket.
fn long_prompt() -> String {
    let mut p = "pad ".repeat(1500);
    p.push_str("Q mira hue ? A");
    p
}

/// Drive `STEPS` tokens; the first step goes through `send_prompt`
/// (chunked when prefill is enabled, the monolithic fallback
/// otherwise).  Returns (tokens, prompt-phase wire bytes, TTFT us).
fn drive(c: &mut DeviceClient, prompt: &str) -> (Vec<i32>, u64, f64) {
    let mut ctx = tokenizer::encode_prompt(prompt);
    let mut toks = Vec::with_capacity(STEPS);
    let b0 = c.stats.bytes_sent;
    let t0 = Instant::now();
    let (t, _) = c.send_prompt(&ctx).expect("prompt");
    let ttft_us = t0.elapsed().as_secs_f64() * 1e6;
    let prompt_bytes = c.stats.bytes_sent - b0;
    ctx.push(t);
    toks.push(t);
    for _ in 1..STEPS {
        let (t, _) = c.step(&ctx).expect("step");
        ctx.push(t);
        toks.push(t);
    }
    (toks, prompt_bytes, ttft_us)
}

fn main() {
    let mut out = Json::obj();
    let cfg = PrefillConfig { chunk_rows: CHUNK_ROWS,
                              drift_threshold: DRIFT_THR };

    // ------------------------------------------------------------------
    // leg 1: the real serving core, monolithic vs chunked prompt
    // ------------------------------------------------------------------
    let store = Arc::new(forged_longctx_store("prefill_bench").expect("forge"));
    let scfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
    ]).unwrap();
    let handle = start_service(&scfg, store.clone()).expect("service");
    let prompt = long_prompt();
    let n_prompt = tokenizer::encode_prompt(&prompt).len();
    assert!(n_prompt > 1000, "prompt is only {n_prompt} tokens — the \
                              long-context scenario wants thousands");

    // monolithic: prefill never enabled, send_prompt falls back to the
    // full-plane recompute step
    let mut mono = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 1).unwrap();
    let (mono_tokens, mono_bytes, mono_ttft) = drive(&mut mono, &prompt);
    assert_eq!(mono.stats.prefill_chunks, 0);
    mono.bye().unwrap();

    // chunked prefill
    let mut ch = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 2).unwrap();
    assert!(ch.enable_prefill(cfg), "prefill capability must negotiate");
    let (ch_tokens, ch_bytes, ch_ttft) = drive(&mut ch, &prompt);
    assert_eq!(ch_tokens, mono_tokens,
               "chunked prefill moved the generated tokens — the \
                Parseval-bounded chunk budget must not change the output");
    assert_eq!(ch.stats.prefill_prompts, 1);
    assert_eq!(ch.stats.prefill_resyncs, 0);
    let (chunks, key_chunks) =
        (ch.stats.prefill_chunks, ch.stats.prefill_key_chunks);
    assert!(chunks >= 4, "only {chunks} chunks — the 2048-bucket plane \
                          must split into many at {CHUNK_ROWS} rows");
    ch.bye().unwrap();
    handle.shutdown();

    let serve_x = mono_bytes as f64 / ch_bytes.max(1) as f64;
    println!("serving prompt ({n_prompt} tokens, bucket 2048): monolithic \
              {mono_bytes} B vs chunked {ch_bytes} B ({serve_x:.2}x, \
              {chunks} chunks / {key_chunks} keyframe), TTFT \
              {mono_ttft:.0} us vs {ch_ttft:.0} us");
    assert!(serve_x >= 2.0,
            "chunked prefill saved only {serve_x:.2}x prompt-phase wire \
             bytes on the served long-context scenario (need >= 2x)");

    out.set("prompt_tokens", Json::Num(n_prompt as f64));
    out.set("steps", Json::Num(STEPS as f64));
    out.set("chunk_rows", Json::Num(CHUNK_ROWS as f64));
    out.set("drift_threshold", Json::Num(DRIFT_THR));
    out.set("serve_mono_prompt_bytes", Json::Num(mono_bytes as f64));
    out.set("serve_chunked_prompt_bytes", Json::Num(ch_bytes as f64));
    out.set("serve_savings_x", Json::Num(serve_x));
    out.set("serve_mono_ttft_us", Json::Num(mono_ttft.round()));
    out.set("serve_chunked_ttft_us", Json::Num(ch_ttft.round()));
    out.set("serve_chunks", Json::Num(chunks as f64));
    out.set("serve_key_chunks", Json::Num(key_chunks as f64));
    out.set("token_parity", Json::Bool(true));

    // ------------------------------------------------------------------
    // leg 2: codec-level on the band-limited family at the same
    // geometry — every chunk reassembled bit-exact, same >= 2x gate
    // ------------------------------------------------------------------
    let spec = fourier_compress::testkit::ForgeSpec::tiny_longctx();
    let ladder = fourier_compress::testkit::bucket_ladder(
        2048, spec.d_model, spec.l1_freq_bins, &spec.ladder_kds, spec.ratio)
        .expect("ladder");
    let geom = BlockGeom { rows: 2048, cols: spec.d_model,
                           ks: ladder[0].ks, kd: ladder[0].kd };
    let act = band_limited_act(geom.rows, geom.cols, spec.l1_freq_bins,
                               0x9F11);
    let fc = FourierCodec::default();
    let p = fc.compress_block(&act, geom.rows, geom.cols, geom.ks, geom.kd)
        .expect("fc compress");
    let n = geom.ks * geom.kd;
    assert_eq!(p.body.len(), 4 + n * 4, "unexpected fc payload layout");
    let plane: Vec<f32> = p.body[4..].chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let mut eng = CodecEngine::new();
    let (mut chunks2, mut state) = (Vec::new(), Vec::new());
    let drift = split_prefill(&mut eng, geom, &plane, cfg, &mut chunks2,
                              &mut state).expect("split");
    assert!(drift <= DRIFT_THR + 1e-9, "drift {drift} over threshold");
    let mut asm = PrefillAssembler::new();
    let mut done = None;
    let mut chunk_bytes = 0u64;
    for c in &chunks2 {
        // the wire-framed chunk round-trips exactly too
        let f = Frame::PrefillChunk {
            session: 1, request: 1, bucket: geom.rows as u16,
            true_len: geom.rows as u16, ks: geom.ks as u16,
            kd: geom.kd as u16, point: 0, index: c.index, last: c.last,
            keyframe: c.keyframe, packed: c.packed.clone(),
            updates: c.updates.clone(), coded: vec![],
        };
        let enc = f.encode();
        let back = Frame::read_from(&mut std::io::Cursor::new(enc)).unwrap();
        assert_eq!(back, f, "chunk {} frame roundtrip", c.index);
        chunk_bytes += (c.body_bytes() + PREFILL_HEADER_BYTES) as u64;
        done = asm.apply(geom, c.index, c.last, c.keyframe, &c.packed,
                         &c.updates).expect("apply");
    }
    let assembled = done.expect("last chunk completes the plane");
    assert!(assembled.iter().map(|v| v.to_bits())
                .eq(state.iter().map(|v| v.to_bits())),
            "reassembled plane is not bit-exact against the encoder state");
    let mono2 = (n * 4 + PREFILL_HEADER_BYTES) as u64;
    let codec_x = mono2 as f64 / chunk_bytes as f64;
    println!("codec plane ({}x{} block {}x{}): monolithic {mono2} B vs \
              {} chunks {chunk_bytes} B ({codec_x:.2}x), drift {drift:.2e}",
             geom.rows, geom.cols, geom.ks, geom.kd, chunks2.len());
    assert!(codec_x >= 2.0,
            "chunked prefill saved only {codec_x:.2}x wire bytes at the \
             codec level (need >= 2x)");

    out.set("codec_geometry", Json::Str(format!(
        "{}x{} block {}x{}", geom.rows, geom.cols, geom.ks, geom.kd)));
    out.set("codec_mono_bytes", Json::Num(mono2 as f64));
    out.set("codec_chunked_bytes", Json::Num(chunk_bytes as f64));
    out.set("codec_savings_x", Json::Num(codec_x));
    out.set("codec_chunks", Json::Num(chunks2.len() as f64));
    out.set("codec_drift", Json::Num(drift));
    out.set("chunks_bit_exact", Json::Bool(true));

    // the Fig-7 byte model's chunked-prefill column, for cross-checking
    // the DES against what the real wire just measured
    let sim = SimConfig { prompt_tokens: n_prompt,
                          prefill_chunks: chunks2.len(),
                          ..SimConfig::default() };
    out.set("sim_model_savings_x",
            Json::Num(prompt_bytes(&sim, Arm::Fc)
                      / prompt_bytes(&sim, Arm::FcStream)));

    std::fs::write("BENCH_prefill.json", out.to_string_pretty())
        .expect("write BENCH_prefill.json");
    println!("wrote BENCH_prefill.json");
}
