//! Fig 7 — multi-client scalability under varying 6G link rates, both
//! regimes: (a) 1 compute unit (compute-bound), (b) 8 compute units
//! (bandwidth-bound).  Prints the saturation analysis and writes
//! results/fig7_units{1,8}.json.

use fourier_compress::config::SimConfig;
use fourier_compress::sim;
use fourier_compress::util::json::Json;

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("results")?;
    for units in [1usize, 8] {
        let cfg = SimConfig {
            compute_units: units,
            // regime calibration (DESIGN.md §2 substitution table):
            // 1 unit = paper's single 4090 without batching headroom;
            // 8 units = the batched multi-GPU pipeline
            service_per_token_s: if units == 1 { 4.0e-3 } else { 1.2e-4 },
            ..SimConfig::default()
        };
        println!("\n== Fig 7({}) — {units} compute unit(s) ==",
                 if units == 1 { 'a' } else { 'b' });
        let j = sim::fig7(&cfg);
        std::fs::write(format!("results/fig7_units{units}.json"),
                       j.to_string_pretty())?;

        // saturation summary: max clients with mean response < 2x the
        // single-client latency (the paper's "supported clients" notion)
        for &g in &cfg.link_gbps {
            for tag in ["orig", "fc", "fcs"] {
                let means = j.get(&format!("{tag}_{g}gbps_mean_s"))
                    .and_then(|v| v.as_arr()).unwrap();
                let base = means[0].as_f64().unwrap_or(f64::NAN);
                let thresh = (base * 2.0).max(0.1);
                let mut cap = cfg.clients[0];
                for (i, m) in means.iter().enumerate() {
                    if m.as_f64().unwrap_or(f64::INFINITY) <= thresh {
                        cap = cfg.clients[i];
                    }
                }
                println!("  {g:>4} Gbps {tag:>5}: base {base:.3}s, \
                          supports ~{cap} clients");
            }
        }
    }
    println!("\nwrote results/fig7_units{{1,8}}.json");
    Ok(())
}
