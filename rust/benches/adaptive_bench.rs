//! Adaptive-rate-control bench: drive the *real* serving core (forged
//! artifacts, in-proc transport) with an adaptive client over a
//! fluctuating channel trace — fast, collapsed ~700x, fast again —
//! and compare its cumulative wire bytes against every fixed ladder
//! point, at bit-identical output tokens.
//!
//! "Best fixed point" is point 0: the paper's offline procedure pins
//! one quality-safe low-frequency block per layer, and a static
//! deployment must ship that point because it cannot know its runtime
//! link.  The forged ladders keep every point inside the model's
//! layer-1 band, so the bench can assert the strongest form of the
//! claim: the adaptive session sends >= 1.3x fewer bytes than the
//! static configuration while generating *exactly* the same tokens,
//! downshifting under the collapsed link and recovering afterwards.
//! Writes BENCH_adaptive.json and hard-asserts all of it so the CI
//! smoke step fails loudly on a regression.
//!
//!     cargo bench --bench adaptive_bench

use fourier_compress::codec::rate::RateConfig;
use fourier_compress::config::{FromJson, ServeConfig, SimConfig};
use fourier_compress::coordinator::{start_service, DeviceClient,
                                    ShapedTransport};
use fourier_compress::model::tokenizer;
use fourier_compress::net::{Channel, ChannelTrace, DropPlan};
use fourier_compress::sim::{bytes_per_step, Arm};
use fourier_compress::testkit::{forged_store_with, ForgeSpec};
use fourier_compress::util::json::Json;
use std::sync::Arc;

const STEPS: usize = 22;
const PROMPT: &str = "Q rok ? A"; // 10 tokens; 22 steps stay <= bucket 32

fn gen_steps(c: &mut DeviceClient, steps: usize) -> (Vec<i32>, u64) {
    let mut ctx = tokenizer::encode_prompt(PROMPT);
    let b0 = c.stats.bytes_sent;
    let mut toks = Vec::with_capacity(steps);
    for _ in 0..steps {
        let (t, _) = c.step(&ctx).expect("step");
        ctx.push(t);
        toks.push(t);
    }
    (toks, c.stats.bytes_sent - b0)
}

fn main() {
    let store = Arc::new(forged_store_with(
        "adaptive_bench", &[ForgeSpec::tiny_adaptive()], "forge-adapt")
        .expect("forge artifacts"));
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
    ]).unwrap();
    let handle = start_service(&cfg, store.clone()).expect("service");

    let ladder_len = store.manifest.path("serving.buckets.16")
        .and_then(|b| b.get("ladder"))
        .and_then(|l| l.as_arr())
        .map(|l| l.len())
        .expect("manifest ladder");

    // reference: a plain (non-adaptive) client — the static point-0
    // deployment — on an unshaped link; bytes are link-independent
    let mut base_client = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 1).unwrap();
    let (base_tokens, _) = gen_steps(&mut base_client, STEPS);
    base_client.bye().unwrap();

    // every fixed ladder point, pinned: bytes per point + token parity
    let mut fixed_bytes = Vec::with_capacity(ladder_len);
    for point in 0..ladder_len {
        let mut c = DeviceClient::connect_over(
            Box::new(handle.connect_inproc()), &store, 10 + point as u64)
            .unwrap();
        assert!(c.pin_ladder_point(point as u8), "pin point {point}");
        let (toks, bytes) = gen_steps(&mut c, STEPS);
        assert_eq!(toks, base_tokens,
                   "fixed point {point} moved the output tokens — the \
                    forged ladder must stay inside the layer-1 band");
        c.bye().unwrap();
        fixed_bytes.push(bytes);
        println!("fixed point {point}: {bytes} B over {STEPS} steps");
    }
    assert!(fixed_bytes.windows(2).all(|w| w[1] < w[0]),
            "ladder points must be strictly cheaper down the ladder: \
             {fixed_bytes:?}");

    // the adaptive client over the fluctuating trace: sends 0..=2
    // (hello + 2 steps) fast, 3..=16 collapsed ~700x, then fast
    let fast = Channel::gbps(0.05, 0); // 50 Mbit/s
    let slow = Channel::gbps(0.00005, 0); // 50 kbit/s
    let trace = ChannelTrace::new(&[(3, fast), (14, slow), (1, fast)]);
    let transport = ShapedTransport::with_trace(
        Box::new(handle.connect_inproc()), trace, DropPlan::none());
    let mut ac = DeviceClient::connect_over(Box::new(transport), &store, 99)
        .unwrap();
    assert!(ac.enable_adaptive(RateConfig {
        error_budget: 1.0,
        target_step_s: 0.025,
        ewma_alpha: 0.7,
        min_dwell_steps: 2,
        up_margin: 1.5,
    }), "ladder capability must negotiate");
    let (adaptive_tokens, adaptive_bytes) = gen_steps(&mut ac, STEPS);
    let (switches, max_point, end_point) =
        (ac.stats.ladder_switches, ac.stats.max_point, ac.current_point());
    ac.bye().unwrap();
    handle.shutdown();

    assert_eq!(adaptive_tokens, base_tokens,
               "adaptive ladder riding moved the output tokens");
    assert!(max_point > 0, "adaptive client never downshifted");
    assert_eq!(end_point, 0, "adaptive client never recovered point 0");
    let best_fixed = fixed_bytes[0];
    let savings = best_fixed as f64 / adaptive_bytes.max(1) as f64;
    println!("adaptive: {adaptive_bytes} B ({switches} switches, deepest \
              point {max_point}) vs best fixed {best_fixed} B -> \
              {savings:.2}x");
    assert!(adaptive_bytes <= best_fixed,
            "adaptive ({adaptive_bytes} B) sent more than the static \
             point-0 deployment ({best_fixed} B)");
    assert!(savings >= 1.3,
            "adaptive saved only {savings:.2}x over the best fixed point \
             (need >= 1.3x)");

    // the Fig-7 byte model's adaptive arm over the same horizon
    let sim_cfg = SimConfig::default();
    let cum = |arm: Arm| -> f64 {
        (0..128).map(|t| bytes_per_step(&sim_cfg, arm, t)).sum()
    };

    let mut out = Json::obj();
    out.set("steps", Json::Num(STEPS as f64));
    out.set("trace", Json::Str(
        "3 frames @50Mbps | 14 @50kbps | rest @50Mbps".into()));
    out.set("ladder_points", Json::Num(ladder_len as f64));
    out.set("fixed_bytes", Json::Arr(
        fixed_bytes.iter().map(|&b| Json::Num(b as f64)).collect()));
    out.set("adaptive_bytes", Json::Num(adaptive_bytes as f64));
    out.set("savings_vs_best_fixed_x", Json::Num(savings));
    out.set("adaptive_switches", Json::Num(switches as f64));
    out.set("adaptive_max_point", Json::Num(max_point as f64));
    out.set("token_parity", Json::Bool(true));
    out.set("model_fcs_bytes", Json::Num(cum(Arm::FcStream)));
    out.set("model_fca_bytes", Json::Num(cum(Arm::FcAdaptive)));
    std::fs::write("BENCH_adaptive.json", out.to_string_pretty())
        .expect("write BENCH_adaptive.json");
    println!("wrote BENCH_adaptive.json");
}
