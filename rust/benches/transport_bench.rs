//! Transport overhead bench: the same serving step — fixed context,
//! fixed bucket, one compressed activation up, one token back —
//! driven through the same running service core over (a) loopback
//! TCP and (b) the zero-socket in-proc transport.  The spread between
//! the two is the per-step cost of the OS network stack, which the
//! serving API v2 made swappable.  Writes BENCH_transport.json.
//!
//!     cargo bench --bench transport_bench

use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::{DeviceClient, EdgeServer};
use fourier_compress::model::tokenizer;
use fourier_compress::net::Channel;
use fourier_compress::testkit::forged_store;
use fourier_compress::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

const STEPS: usize = 64;

/// Drive STEPS identical decode steps (the context never grows, so
/// every step ships the same bucket) and return (mean us/step, wire
/// bytes, tokens).
fn run_steps(client: &mut DeviceClient, ctx: &[i32])
    -> (f64, u64, Vec<i32>) {
    // one warm-up step: engine caches, artifact load, first batch
    client.step(ctx).expect("warm-up step");
    let bytes_before = client.stats.bytes_sent;
    let mut tokens = Vec::with_capacity(STEPS);
    let t0 = Instant::now();
    for _ in 0..STEPS {
        let (t, _lp) = client.step(ctx).expect("bench step");
        tokens.push(t);
    }
    let us = t0.elapsed().as_micros() as f64 / STEPS as f64;
    (us, client.stats.bytes_sent - bytes_before, tokens)
}

fn main() {
    let store = Arc::new(forged_store("transport_bench").expect("forge"));
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
    ]).unwrap();
    let server = EdgeServer::start(cfg, store.clone()).unwrap();
    let addr = server.addr.to_string();
    // BOS + 14 bytes = 15 tokens: pinned inside the 16-token bucket
    let ctx = tokenizer::encode_prompt("Q mira hue ? A");
    assert!(ctx.len() <= 16, "prompt must stay in the smallest bucket");

    let mut tcp = DeviceClient::connect(&addr, &store, 1,
                                        Channel::unlimited()).unwrap();
    let (tcp_us, tcp_bytes, tcp_tokens) = run_steps(&mut tcp, &ctx);
    tcp.bye().unwrap();

    let mut inproc = DeviceClient::connect_over(
        Box::new(server.connect_inproc()), &store, 2).unwrap();
    let (ip_us, ip_bytes, ip_tokens) = run_steps(&mut inproc, &ctx);
    inproc.bye().unwrap();

    // same step, same service: the media must agree on bytes + tokens
    assert_eq!(tcp_bytes, ip_bytes, "wire accounting diverged across media");
    assert_eq!(tcp_tokens, ip_tokens, "tokens diverged across media");

    println!("{STEPS} steps, bucket 16: tcp {tcp_us:.1} us/step, \
              in-proc {ip_us:.1} us/step (spread {:.1} us), \
              {} B/step", tcp_us - ip_us, tcp_bytes / STEPS as u64);

    let mut out = Json::obj();
    out.set("steps", Json::Num(STEPS as f64));
    out.set("bucket", Json::Num(16.0));
    out.set("tcp_us_per_step", Json::Num(tcp_us));
    out.set("inproc_us_per_step", Json::Num(ip_us));
    out.set("tcp_overhead_us_per_step", Json::Num(tcp_us - ip_us));
    out.set("bytes_per_step", Json::Num((tcp_bytes / STEPS as u64) as f64));
    std::fs::write("BENCH_transport.json", out.to_string_pretty())
        .expect("write BENCH_transport.json");
    println!("wrote BENCH_transport.json");
    server.shutdown();
}
