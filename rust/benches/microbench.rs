//! Hot-path microbenchmarks — the §Perf iteration targets: FFT plans,
//! 2-D transforms, conjugate-symmetric pack/unpack, wire framing,
//! top-k selection, and the QR/SVD inner loops at eval sizes.

use fourier_compress::codec::fourier::{pack_block, unpack_block, FourierCodec};
use fourier_compress::codec::Codec;
use fourier_compress::coordinator::protocol::Frame;
use fourier_compress::dsp::complex::C64;
use fourier_compress::dsp::fft::FftPlan;
use fourier_compress::dsp::fft2d::{fft2, fft2_real};
use fourier_compress::linalg::matrix::Mat;
use fourier_compress::linalg::{qr_thin, svd_thin};
use fourier_compress::util::bench::bench;
use fourier_compress::util::rng::Rng;
use std::time::Duration;

fn main() {
    let budget = Duration::from_secs(4);
    let mut rng = Rng::new(1);

    // 1-D FFT across the sizes the codec hits
    for n in [64usize, 96, 128, 256, 1536, 2048, 3072] {
        let plan = FftPlan::new(n);
        let base: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), 0.0)).collect();
        bench(&format!("fft1d n={n}"), 200, budget, || {
            let mut x = base.clone();
            plan.forward_in_place(&mut x);
            std::hint::black_box(&x);
        });
    }

    // 2-D FFT at eval + Table-IV sizes
    for (r, c) in [(64usize, 128usize), (256, 2048)] {
        let a: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
        bench(&format!("fft2d {r}x{c}"), 50, budget, || {
            std::hint::black_box(fft2_real(&a, r, c));
        });
        let mut buf: Vec<C64> = a.iter().map(|&v| C64::from_re(v as f64)).collect();
        bench(&format!("fft2d inplace {r}x{c}"), 50, budget, || {
            fft2(&mut buf, r, c);
        });
    }

    // the full software codec round trip at serving size
    let (s, d) = (64usize, 128usize);
    let a: Vec<f32> = (0..s * d).map(|_| rng.normal() as f32).collect();
    let fc = FourierCodec::with_hint(15);
    bench("fc roundtrip 64x128 r8", 200, budget, || {
        std::hint::black_box(fc.roundtrip(&a, s, d, 8.0).unwrap());
    });

    // pack/unpack of the serving block
    let p = fc.compress_block(&a, s, d, 64, 15).unwrap();
    let (re, im) = unpack_block(&p.body[4..].chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect::<Vec<_>>(), s, d, 64, 15).unwrap();
    bench("pack_block 64x15", 500, budget, || {
        std::hint::black_box(pack_block(&re, &im, s, d, 64, 15));
    });
    let packed = pack_block(&re, &im, s, d, 64, 15);
    bench("unpack_block 64x15", 500, budget, || {
        std::hint::black_box(unpack_block(&packed, s, d, 64, 15).unwrap());
    });

    // wire framing
    let frame = Frame::Activation {
        session: 1, request: 2, bucket: 64, true_len: 60, ks: 64, kd: 15,
        packed: packed.clone(),
    };
    bench("frame encode+decode", 500, budget, || {
        let enc = frame.encode();
        let mut cur = std::io::Cursor::new(enc);
        std::hint::black_box(Frame::read_from(&mut cur).unwrap());
    });

    // top-k selection at serving size
    let tk = fourier_compress::codec::topk::TopkCodec;
    bench("topk roundtrip 64x128 r8", 200, budget, || {
        std::hint::black_box(tk.roundtrip(&a, s, d, 8.0).unwrap());
    });

    // factorizations at eval size
    let m = Mat::from_f32(&a, s, d);
    bench("qr_thin 64x128", 50, budget, || {
        std::hint::black_box(qr_thin(&m));
    });
    bench("svd_thin 64x128", 10, budget, || {
        std::hint::black_box(svd_thin(&m));
    });

    // matmul kernel shape used by factor reconstruction
    let b = Mat::from_f32(&a, d, s);
    bench("matmul 64x128x64", 100, budget, || {
        std::hint::black_box(m.matmul(&b));
    });
}
