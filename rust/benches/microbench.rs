//! Hot-path microbenchmarks — the §Perf iteration targets: FFT plans,
//! 2-D transforms, conjugate-symmetric pack/unpack, wire framing,
//! top-k selection, and the QR/SVD inner loops at eval sizes — plus
//! the engine-vs-legacy codec comparison at the Table-IV serving size,
//! recorded to BENCH_codec.json so the perf trajectory is tracked
//! across PRs.

use fourier_compress::codec::fourier::{pack_block, unpack_block, FourierCodec};
use fourier_compress::codec::{Codec, CodecEngine, Payload};
use fourier_compress::coordinator::protocol::Frame;
use fourier_compress::dsp::complex::C64;
use fourier_compress::dsp::fft::FftPlan;
use fourier_compress::dsp::fft2d::{fft2, fft2_real};
use fourier_compress::linalg::matrix::Mat;
use fourier_compress::linalg::{qr_thin, svd_thin};
use fourier_compress::tensor::MatView;
use fourier_compress::util::bench::bench;
use fourier_compress::util::json::Json;
use fourier_compress::util::rng::Rng;
use std::time::Duration;

fn main() {
    let budget = Duration::from_secs(4);
    let mut rng = Rng::new(1);

    // 1-D FFT across the sizes the codec hits
    for n in [64usize, 96, 128, 256, 1536, 2048, 3072] {
        let plan = FftPlan::new(n);
        let base: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), 0.0)).collect();
        bench(&format!("fft1d n={n}"), 200, budget, || {
            let mut x = base.clone();
            plan.forward_in_place(&mut x);
            std::hint::black_box(&x);
        });
    }

    // 2-D FFT at eval + Table-IV sizes
    for (r, c) in [(64usize, 128usize), (256, 2048)] {
        let a: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
        bench(&format!("fft2d {r}x{c}"), 50, budget, || {
            std::hint::black_box(fft2_real(MatView::new(&a, r, c)));
        });
        let mut buf: Vec<C64> = a.iter().map(|&v| C64::from_re(v as f64)).collect();
        bench(&format!("fft2d inplace {r}x{c}"), 50, budget, || {
            fft2(&mut buf, r, c);
        });
    }

    // the full software codec round trip at serving size
    let (s, d) = (64usize, 128usize);
    let a: Vec<f32> = (0..s * d).map(|_| rng.normal() as f32).collect();
    let fc = FourierCodec::with_hint(15);
    bench("fc roundtrip 64x128 r8", 200, budget, || {
        std::hint::black_box(fc.roundtrip(&a, s, d, 8.0).unwrap());
    });

    // pack/unpack of the serving block
    let p = fc.compress_block(&a, s, d, 64, 15).unwrap();
    let (re, im) = unpack_block(&p.body[4..].chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect::<Vec<_>>(), s, d, 64, 15).unwrap();
    bench("pack_block 64x15", 500, budget, || {
        std::hint::black_box(pack_block(&re, &im, s, d, 64, 15));
    });
    let packed = pack_block(&re, &im, s, d, 64, 15);
    bench("unpack_block 64x15", 500, budget, || {
        std::hint::black_box(unpack_block(&packed, s, d, 64, 15).unwrap());
    });

    // wire framing
    let frame = Frame::Activation {
        session: 1, request: 2, bucket: 64, true_len: 60, ks: 64, kd: 15,
        point: 0, packed: packed.clone(),
    };
    bench("frame encode+decode", 500, budget, || {
        let enc = frame.encode();
        let mut cur = std::io::Cursor::new(enc);
        std::hint::black_box(Frame::read_from(&mut cur).unwrap());
    });

    // top-k selection at serving size
    let tk = fourier_compress::codec::topk::TopkCodec;
    bench("topk roundtrip 64x128 r8", 200, budget, || {
        std::hint::black_box(tk.roundtrip(&a, s, d, 8.0).unwrap());
    });

    // factorizations at eval size
    let m = Mat::from_f32(&a, s, d);
    bench("qr_thin 64x128", 50, budget, || {
        std::hint::black_box(qr_thin(&m));
    });
    bench("svd_thin 64x128", 10, budget, || {
        std::hint::black_box(svd_thin(&m));
    });

    // matmul kernel shape used by factor reconstruction
    let b = Mat::from_f32(&a, d, s);
    bench("matmul 64x128x64", 100, budget, || {
        std::hint::black_box(m.matmul(&b));
    });

    // ---------------------------------------------------------------
    // engine vs one-shot at the Table-IV serving size (256 x 2048,
    // r8), three arms:
    //   * cold    — a fresh CodecEngine per call: reproduces the
    //               pre-engine cost model (scratch reallocated, index
    //               sets re-derived, plans from the shared tier),
    //   * oneshot — the legacy `Codec::compress` API (thread-local
    //               engine, but per-call Payload/output allocation),
    //   * engine  — warm caller-owned engine + reused buffers (zero
    //               steady-state allocation).
    // Emits BENCH_codec.json so the perf trajectory is recorded.
    // ---------------------------------------------------------------
    let (bs, bd, ratio) = (256usize, 2048usize, 8.0f64);
    let big: Vec<f32> = {
        let mut rng = Rng::new((bs + bd) as u64);
        (0..bs * bd).map(|_| rng.normal() as f32).collect()
    };
    let fc = FourierCodec::default();
    let view = MatView::new(&big, bs, bd);

    let cold_c = bench(&format!("fc cold compress {bs}x{bd} r{ratio:.0}"),
                       60, budget, || {
        let mut e = CodecEngine::new();
        let mut p = Payload::empty();
        fc.compress_into(&mut e, view, ratio, &mut p).unwrap();
        std::hint::black_box(&p);
    });
    let legacy_p = fc.compress(&big, bs, bd, ratio).unwrap();
    let cold_d = bench(&format!("fc cold decompress {bs}x{bd}"),
                       60, budget, || {
        let mut e = CodecEngine::new();
        let mut out = Vec::new();
        fc.decompress_into(&mut e, &legacy_p, &mut out).unwrap();
        std::hint::black_box(&out);
    });

    let oneshot_c = bench(&format!("fc oneshot compress {bs}x{bd} r{ratio:.0}"),
                          60, budget, || {
        std::hint::black_box(fc.compress(&big, bs, bd, ratio).unwrap());
    });
    let oneshot_d = bench(&format!("fc oneshot decompress {bs}x{bd}"),
                          60, budget, || {
        std::hint::black_box(fc.decompress(&legacy_p).unwrap());
    });

    let mut eng = CodecEngine::new();
    let mut payload = Payload::empty();
    let mut recon: Vec<f32> = Vec::new();
    // warm-up: fills plan/index caches and grows the scratch arena
    fc.compress_into(&mut eng, view, ratio, &mut payload).unwrap();
    fc.decompress_into(&mut eng, &payload, &mut recon).unwrap();
    assert_eq!(payload, legacy_p, "engine/legacy wire parity");
    let warm_scratch = eng.scratch_bytes();

    let engine_c = bench(&format!("fc engine compress {bs}x{bd} r{ratio:.0}"),
                         60, budget, || {
        fc.compress_into(&mut eng, view, ratio, &mut payload).unwrap();
        std::hint::black_box(&payload);
    });
    let engine_d = bench(&format!("fc engine decompress {bs}x{bd}"),
                         60, budget, || {
        fc.decompress_into(&mut eng, &payload, &mut recon).unwrap();
        std::hint::black_box(&recon);
    });
    assert_eq!(eng.scratch_bytes(), warm_scratch,
               "scratch arena grew after warm-up");

    // int8 at the same serving size — pins the hoisted per-block
    // scale reciprocal (one divide per block, not one per element)
    let int8 = fourier_compress::codec::quant::Int8Codec::default();
    let mut p8 = Payload::empty();
    int8.compress_into(&mut eng, view, 4.0, &mut p8).unwrap();
    let int8_c = bench(&format!("int8 engine compress {bs}x{bd}"), 100, budget,
                       || {
        int8.compress_into(&mut eng, view, 4.0, &mut p8).unwrap();
        std::hint::black_box(&p8);
    });

    let speedup_c = cold_c.median.as_secs_f64() / engine_c.median.as_secs_f64();
    let speedup_d = cold_d.median.as_secs_f64() / engine_d.median.as_secs_f64();
    println!("engine vs pre-engine cost model: \
              compress {speedup_c:.2}x decompress {speedup_d:.2}x");

    let mut out = Json::obj();
    out.set("shape", Json::Str(format!("{bs}x{bd}")));
    out.set("ratio", Json::Num(ratio));
    out.set("cold_compress_s", Json::Num(cold_c.median.as_secs_f64()));
    out.set("cold_decompress_s", Json::Num(cold_d.median.as_secs_f64()));
    out.set("oneshot_compress_s", Json::Num(oneshot_c.median.as_secs_f64()));
    out.set("oneshot_decompress_s", Json::Num(oneshot_d.median.as_secs_f64()));
    out.set("engine_compress_s", Json::Num(engine_c.median.as_secs_f64()));
    out.set("engine_decompress_s", Json::Num(engine_d.median.as_secs_f64()));
    out.set("int8_compress_s", Json::Num(int8_c.median.as_secs_f64()));
    out.set("compress_speedup_vs_cold", Json::Num(speedup_c));
    out.set("decompress_speedup_vs_cold", Json::Num(speedup_d));
    out.set("scratch_bytes", Json::Num(warm_scratch as f64));
    out.set("wire_ratio", Json::Num(payload.wire_ratio()));
    out.set("achieved_ratio", Json::Num(payload.achieved_ratio()));
    std::fs::write("BENCH_codec.json", out.to_string_pretty())
        .expect("write BENCH_codec.json");
    println!("wrote BENCH_codec.json");
}
