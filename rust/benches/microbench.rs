//! Hot-path microbenchmarks — the §Perf iteration targets: FFT plans,
//! 2-D transforms, conjugate-symmetric pack/unpack, wire framing,
//! top-k selection, and the QR/SVD inner loops at eval sizes — plus
//! the codec comparison at the Table-IV serving size (256 x 2048, r8),
//! recorded to BENCH_codec.json so the perf trajectory is tracked
//! across PRs.
//!
//! The serving-size comparison runs four arms:
//!   * baseline — the pre-rfft pipeline (`fourier::baseline`): row-pair
//!                complex FFTs, full complex inverse, allocating —
//!                the reference this PR's speedup is measured against,
//!   * cold     — a fresh CodecEngine per call (pre-engine cost model),
//!   * scalar   — warm engine with vector kernels disabled,
//!   * engine   — warm engine at the process-detected SIMD level.
//! It asserts the scalar and SIMD arms are wire-byte and output-bit
//! identical, and (on a `--features simd` build) that the engine
//! compress beats the baseline by >= 1.5x.
//!
//! `--smoke` shrinks budgets for CI: the parity and speedup assertions
//! still run, only the generic sweeps are skipped.

use fourier_compress::codec::fourier::{baseline, pack_block, unpack_block,
                                       FourierCodec};
use fourier_compress::codec::{rel_error, Codec, CodecEngine, Payload};
use fourier_compress::coordinator::protocol::Frame;
use fourier_compress::dsp::complex::C64;
use fourier_compress::dsp::fft::FftPlan;
use fourier_compress::dsp::fft2d::{fft2, fft2_real};
use fourier_compress::linalg::matrix::Mat;
use fourier_compress::linalg::{qr_thin, svd_thin};
use fourier_compress::tensor::MatView;
use fourier_compress::util::bench::bench;
use fourier_compress::util::json::Json;
use fourier_compress::util::rng::Rng;
use std::time::Duration;

fn generic_sweeps(budget: Duration) {
    let mut rng = Rng::new(1);

    // 1-D FFT across the sizes the codec hits
    for n in [64usize, 96, 128, 256, 1536, 2048, 3072] {
        let plan = FftPlan::new(n);
        let base: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), 0.0)).collect();
        bench(&format!("fft1d n={n}"), 200, budget, || {
            let mut x = base.clone();
            plan.forward_in_place(&mut x);
            std::hint::black_box(&x);
        });
    }

    // 2-D FFT at eval + Table-IV sizes
    for (r, c) in [(64usize, 128usize), (256, 2048)] {
        let a: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
        bench(&format!("fft2d {r}x{c}"), 50, budget, || {
            std::hint::black_box(fft2_real(MatView::new(&a, r, c)));
        });
        let mut buf: Vec<C64> = a.iter().map(|&v| C64::from_re(v as f64)).collect();
        bench(&format!("fft2d inplace {r}x{c}"), 50, budget, || {
            fft2(&mut buf, r, c);
        });
    }

    // the full software codec round trip at serving size
    let (s, d) = (64usize, 128usize);
    let a: Vec<f32> = (0..s * d).map(|_| rng.normal() as f32).collect();
    let fc = FourierCodec::with_hint(15);
    bench("fc roundtrip 64x128 r8", 200, budget, || {
        std::hint::black_box(fc.roundtrip(&a, s, d, 8.0).unwrap());
    });

    // pack/unpack of the serving block
    let p = fc.compress_block(&a, s, d, 64, 15).unwrap();
    let (re, im) = unpack_block(&p.body[4..].chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect::<Vec<_>>(), s, d, 64, 15).unwrap();
    bench("pack_block 64x15", 500, budget, || {
        std::hint::black_box(pack_block(&re, &im, s, d, 64, 15));
    });
    let packed = pack_block(&re, &im, s, d, 64, 15);
    bench("unpack_block 64x15", 500, budget, || {
        std::hint::black_box(unpack_block(&packed, s, d, 64, 15).unwrap());
    });

    // wire framing
    let frame = Frame::Activation {
        session: 1, request: 2, bucket: 64, true_len: 60, ks: 64, kd: 15,
        point: 0, packed: packed.clone(),
        coded: vec![],
    };
    bench("frame encode+decode", 500, budget, || {
        let enc = frame.encode();
        let mut cur = std::io::Cursor::new(enc);
        std::hint::black_box(Frame::read_from(&mut cur).unwrap());
    });

    // top-k selection at serving size
    let tk = fourier_compress::codec::topk::TopkCodec;
    bench("topk roundtrip 64x128 r8", 200, budget, || {
        std::hint::black_box(tk.roundtrip(&a, s, d, 8.0).unwrap());
    });

    // factorizations at eval size
    let m = Mat::from_f32(&a, s, d);
    bench("qr_thin 64x128", 50, budget, || {
        std::hint::black_box(qr_thin(&m));
    });
    bench("svd_thin 64x128", 10, budget, || {
        std::hint::black_box(svd_thin(&m));
    });

    // matmul kernel shape used by factor reconstruction
    let b = Mat::from_f32(&a, d, s);
    bench("matmul 64x128x64", 100, budget, || {
        std::hint::black_box(m.matmul(&b));
    });
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let budget = if smoke {
        Duration::from_millis(500)
    } else {
        Duration::from_secs(4)
    };
    if !smoke {
        generic_sweeps(budget);
    }

    // ---------------------------------------------------------------
    // codec comparison at the Table-IV serving size (see module docs
    // for the four arms).  Emits BENCH_codec.json.
    // ---------------------------------------------------------------
    let (bs, bd, ratio) = (256usize, 2048usize, 8.0f64);
    let big: Vec<f32> = {
        let mut rng = Rng::new((bs + bd) as u64);
        (0..bs * bd).map(|_| rng.normal() as f32).collect()
    };
    let fc = FourierCodec::default();
    let view = MatView::new(&big, bs, bd);
    let iters = if smoke { 20 } else { 60 };

    let legacy_p = fc.compress(&big, bs, bd, ratio).unwrap();
    // the (ks, kd) block fc picked at this ratio, off the wire header
    let ks = u16::from_le_bytes([legacy_p.body[0], legacy_p.body[1]]) as usize;
    let kd = u16::from_le_bytes([legacy_p.body[2], legacy_p.body[3]]) as usize;

    // baseline arm: the pre-rfft pipeline at the same block
    let base_p = baseline::compress_block(&big, bs, bd, ks, kd).unwrap();
    assert_eq!(base_p.body.len(), legacy_p.body.len(),
               "baseline/rfft wire length parity");
    let base_c = bench(&format!("fc baseline compress {bs}x{bd} r{ratio:.0}"),
                       iters, budget, || {
        std::hint::black_box(
            baseline::compress_block(&big, bs, bd, ks, kd).unwrap());
    });
    let base_d = bench(&format!("fc baseline decompress {bs}x{bd}"),
                       iters, budget, || {
        std::hint::black_box(baseline::decompress(&base_p).unwrap());
    });

    let cold_c = bench(&format!("fc cold compress {bs}x{bd} r{ratio:.0}"),
                       iters, budget, || {
        let mut e = CodecEngine::new();
        let mut p = Payload::empty();
        fc.compress_into(&mut e, view, ratio, &mut p).unwrap();
        std::hint::black_box(&p);
    });
    let cold_d = bench(&format!("fc cold decompress {bs}x{bd}"),
                       iters, budget, || {
        let mut e = CodecEngine::new();
        let mut out = Vec::new();
        fc.decompress_into(&mut e, &legacy_p, &mut out).unwrap();
        std::hint::black_box(&out);
    });

    let oneshot_c = bench(&format!("fc oneshot compress {bs}x{bd} r{ratio:.0}"),
                          iters, budget, || {
        std::hint::black_box(fc.compress(&big, bs, bd, ratio).unwrap());
    });
    let oneshot_d = bench(&format!("fc oneshot decompress {bs}x{bd}"),
                          iters, budget, || {
        std::hint::black_box(fc.decompress(&legacy_p).unwrap());
    });

    // scalar arm: warm engine, vector kernels pinned off
    let mut seng = CodecEngine::new();
    seng.set_simd_enabled(false);
    let mut spayload = Payload::empty();
    let mut srecon: Vec<f32> = Vec::new();
    fc.compress_into(&mut seng, view, ratio, &mut spayload).unwrap();
    fc.decompress_into(&mut seng, &spayload, &mut srecon).unwrap();
    let scalar_c = bench(&format!("fc scalar compress {bs}x{bd} r{ratio:.0}"),
                         iters, budget, || {
        fc.compress_into(&mut seng, view, ratio, &mut spayload).unwrap();
        std::hint::black_box(&spayload);
    });
    let scalar_d = bench(&format!("fc scalar decompress {bs}x{bd}"),
                         iters, budget, || {
        fc.decompress_into(&mut seng, &spayload, &mut srecon).unwrap();
        std::hint::black_box(&srecon);
    });

    // engine arm: warm engine at the process-detected level
    let mut eng = CodecEngine::new();
    let level = eng.simd_level();
    let mut payload = Payload::empty();
    let mut recon: Vec<f32> = Vec::new();
    // warm-up: fills plan/index caches and grows the scratch arena
    fc.compress_into(&mut eng, view, ratio, &mut payload).unwrap();
    fc.decompress_into(&mut eng, &payload, &mut recon).unwrap();
    assert_eq!(payload, legacy_p, "engine/legacy wire parity");

    // parity contract: the SIMD and scalar arms must agree byte for
    // byte on the wire and bit for bit on the reconstruction
    assert_eq!(payload, spayload, "simd/scalar payload bytes diverge");
    assert_eq!(recon.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
               srecon.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
               "simd/scalar reconstruction bits diverge");
    // ...and the rfft pipeline must reconstruct what baseline does
    // (different FFT factorisation, so bounded-close rather than
    // bit-equal)
    let base_r = baseline::decompress(&base_p).unwrap();
    let drift = rel_error(&base_r, &recon);
    assert!(drift < 1e-5, "rfft recon drifts {drift} from baseline");

    let warm_scratch = eng.scratch_bytes();
    let engine_c = bench(&format!("fc engine compress {bs}x{bd} r{ratio:.0}"),
                         iters, budget, || {
        fc.compress_into(&mut eng, view, ratio, &mut payload).unwrap();
        std::hint::black_box(&payload);
    });
    let engine_d = bench(&format!("fc engine decompress {bs}x{bd}"),
                         iters, budget, || {
        fc.decompress_into(&mut eng, &payload, &mut recon).unwrap();
        std::hint::black_box(&recon);
    });
    assert_eq!(eng.scratch_bytes(), warm_scratch,
               "scratch arena grew after warm-up");

    // int8 at the same serving size — pins the hoisted per-block
    // scale reciprocal and the vector quantize kernel
    let int8 = fourier_compress::codec::quant::Int8Codec::default();
    let mut p8 = Payload::empty();
    int8.compress_into(&mut eng, view, 4.0, &mut p8).unwrap();
    let int8_c = bench(&format!("int8 engine compress {bs}x{bd}"),
                       iters.max(100), budget, || {
        int8.compress_into(&mut eng, view, 4.0, &mut p8).unwrap();
        std::hint::black_box(&p8);
    });

    // per-stage breakdown on the warm engine (timing never perturbs
    // the bytes — pinned by the fourier stage-timer test)
    let stage_iters: u32 = if smoke { 5 } else { 30 };
    eng.enable_stage_timing();
    for _ in 0..stage_iters {
        fc.compress_into(&mut eng, view, ratio, &mut payload).unwrap();
    }
    let ct = eng.stage_times().unwrap();
    eng.enable_stage_timing(); // restart, zeroed
    for _ in 0..stage_iters {
        fc.decompress_into(&mut eng, &payload, &mut recon).unwrap();
    }
    let dt = eng.stage_times().unwrap();
    eng.enable_stage_timing();
    for _ in 0..stage_iters {
        int8.compress_into(&mut eng, view, 4.0, &mut p8).unwrap();
    }
    let qt = eng.stage_times().unwrap();
    eng.disable_stage_timing();
    let per = |d: Duration| d.as_secs_f64() / stage_iters as f64;
    println!("compress stages: row_fft {:.3?} col_fft {:.3?} pack {:.3?} \
              wire {:.3?}", ct.row_fft / stage_iters, ct.col_fft / stage_iters,
             ct.pack / stage_iters, ct.wire / stage_iters);
    println!("decompress stages: row_fft {:.3?} col_fft {:.3?} pack {:.3?} \
              wire {:.3?}", dt.row_fft / stage_iters, dt.col_fft / stage_iters,
             dt.pack / stage_iters, dt.wire / stage_iters);

    let speedup_base_c =
        base_c.median.as_secs_f64() / engine_c.median.as_secs_f64();
    let speedup_base_d =
        base_d.median.as_secs_f64() / engine_d.median.as_secs_f64();
    let speedup_c = cold_c.median.as_secs_f64() / engine_c.median.as_secs_f64();
    let speedup_d = cold_d.median.as_secs_f64() / engine_d.median.as_secs_f64();
    println!("[{}] vs pre-rfft baseline: compress {speedup_base_c:.2}x \
              decompress {speedup_base_d:.2}x; vs pre-engine cost model: \
              compress {speedup_c:.2}x decompress {speedup_d:.2}x",
             level.name());

    // the PR's perf gate: with vector kernels compiled in, the hot
    // path must beat the pre-rfft scalar baseline by 1.5x at the
    // Table-IV serving size while staying byte-identical (asserted
    // above).  Scalar-only builds record the ratio without gating.
    if cfg!(feature = "simd") {
        assert!(speedup_base_c >= 1.5,
                "compress speedup vs baseline {speedup_base_c:.2}x < 1.5x");
    }

    let mut out = Json::obj();
    out.set("shape", Json::Str(format!("{bs}x{bd}")));
    out.set("ratio", Json::Num(ratio));
    out.set("simd", Json::Str(level.name().to_string()));
    out.set("baseline_compress_s", Json::Num(base_c.median.as_secs_f64()));
    out.set("baseline_decompress_s", Json::Num(base_d.median.as_secs_f64()));
    out.set("cold_compress_s", Json::Num(cold_c.median.as_secs_f64()));
    out.set("cold_decompress_s", Json::Num(cold_d.median.as_secs_f64()));
    out.set("oneshot_compress_s", Json::Num(oneshot_c.median.as_secs_f64()));
    out.set("oneshot_decompress_s", Json::Num(oneshot_d.median.as_secs_f64()));
    out.set("scalar_compress_s", Json::Num(scalar_c.median.as_secs_f64()));
    out.set("scalar_decompress_s", Json::Num(scalar_d.median.as_secs_f64()));
    out.set("engine_compress_s", Json::Num(engine_c.median.as_secs_f64()));
    out.set("engine_decompress_s", Json::Num(engine_d.median.as_secs_f64()));
    out.set("int8_compress_s", Json::Num(int8_c.median.as_secs_f64()));
    out.set("compress_speedup_vs_baseline", Json::Num(speedup_base_c));
    out.set("decompress_speedup_vs_baseline", Json::Num(speedup_base_d));
    out.set("compress_speedup_vs_cold", Json::Num(speedup_c));
    out.set("decompress_speedup_vs_cold", Json::Num(speedup_d));
    out.set("stage_compress_row_fft_s", Json::Num(per(ct.row_fft)));
    out.set("stage_compress_col_fft_s", Json::Num(per(ct.col_fft)));
    out.set("stage_compress_pack_s", Json::Num(per(ct.pack)));
    out.set("stage_compress_wire_s", Json::Num(per(ct.wire)));
    out.set("stage_decompress_row_fft_s", Json::Num(per(dt.row_fft)));
    out.set("stage_decompress_col_fft_s", Json::Num(per(dt.col_fft)));
    out.set("stage_decompress_pack_s", Json::Num(per(dt.pack)));
    out.set("stage_decompress_wire_s", Json::Num(per(dt.wire)));
    out.set("stage_int8_quant_s", Json::Num(per(qt.quant)));
    out.set("stage_int8_wire_s", Json::Num(per(qt.wire)));
    out.set("scratch_bytes", Json::Num(warm_scratch as f64));
    out.set("wire_ratio", Json::Num(payload.wire_ratio()));
    out.set("achieved_ratio", Json::Num(payload.achieved_ratio()));
    std::fs::write("BENCH_codec.json", out.to_string_pretty())
        .expect("write BENCH_codec.json");
    println!("wrote BENCH_codec.json");
}
