//! Fig 6 — proportion of compression time in total response time.
//! Composes measured codec time (from the same machinery as the
//! Table-IV bench) with the simulated 6G transfer time of each
//! method's payload and the measured server-side model execution, per
//! method.  Emits results/fig6.json.

use fourier_compress::codec::{self, Codec};
use fourier_compress::coordinator::server::ServingModel;
use fourier_compress::net::Channel;
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::util::bench::once;
use fourier_compress::util::json::Json;
use fourier_compress::util::rng::Rng;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    println!("== Fig 6: codec share of end-to-end response time ==");
    let store = ArtifactStore::open("artifacts")?;
    let serving = ServingModel::load(&store)?;

    // workload: one 64-token prompt step on the serving model
    let (s, d) = (64usize, serving.d_model);
    let mut rng = Rng::new(7);
    let mut a = vec![0.0f32; s * d];
    rng.fill_normal_f32(&mut a, 1.0);
    let channel = Channel::gbps(1.0, 100); // 1 Gbps uplink
    let ratio = 8.0;

    // measured server compute for one batch-1 step (bucket 64)
    let bm = serving.buckets.get(&64).unwrap();
    let item = fourier_compress::coordinator::server::GroupItem {
        session: 0, request: 0, true_len: s,
        re: vec![0.0; bm.ks * bm.kd], im: vec![0.0; bm.ks * bm.kd],
        reply: std::sync::mpsc::channel().0,
        t_rx: Instant::now(),
        trace: None,
    };
    let t0 = Instant::now();
    serving.run_group(64, &[item])?;
    let server_time = t0.elapsed();
    println!("server compute (layers 2..L + head): {server_time:?}");

    let mut out = Json::obj();
    println!("\n{:10} {:>12} {:>12} {:>12} {:>8}", "method", "codec", "transfer",
             "total", "share");
    for name in ["none", "fc", "topk", "qr", "svdllm"] {
        let c = codec::by_name(name)?;
        // Fig 6 models the *transport*: transfer time and the recorded
        // ratio both use framed bytes (Payload::wire_ratio), unlike
        // the Tables II/III accuracy tables which report the body-only
        // Payload::achieved_ratio.
        let mut payload_bytes = 0usize;
        let mut wire_ratio = 1.0f64;
        let codec_time = once(&format!("{name} codec"), || {
            let p = c.compress(&a, s, d, ratio).unwrap();
            payload_bytes = p.wire_bytes();
            wire_ratio = p.wire_ratio();
            std::hint::black_box(c.decompress(&p).unwrap());
        });
        let codec_time = if name == "none" { Duration::ZERO } else { codec_time };
        let transfer = channel.transfer_time(payload_bytes);
        let total = codec_time + transfer + server_time;
        let share = codec_time.as_secs_f64() / total.as_secs_f64();
        println!("{:10} {:>12.3?} {:>12.3?} {:>12.3?} {:>7.1}%",
                 name, codec_time, transfer, total, share * 100.0);
        let mut row = Json::obj();
        row.set("codec_s", Json::Num(codec_time.as_secs_f64()));
        row.set("transfer_s", Json::Num(transfer.as_secs_f64()));
        row.set("server_s", Json::Num(server_time.as_secs_f64()));
        row.set("share", Json::Num(share));
        row.set("wire_ratio", Json::Num(wire_ratio));
        out.set(name, row);
    }

    // hardware-offload proxy for fc
    if let Some(entries) = store.manifest.path("codec_hw.entries")
        .and_then(|v| v.as_arr()) {
        let e = &entries[0];
        let (hs, hd) = (e.usize_or("seq", 0), e.usize_or("hidden", 0));
        let comp = store.get(e.get("compress_mm").unwrap().as_str().unwrap())?;
        let deco = store.get(e.get("decompress_mm").unwrap().as_str().unwrap())?;
        let mut big = vec![0.0f32; hs * hd];
        rng.fill_normal_f32(&mut big, 1.0);
        let at = fourier_compress::tensor::Tensor::f32(vec![hs, hd], big);
        let hw = once("fc(hardware) codec", || {
            let b = comp.run(std::slice::from_ref(&at)).unwrap();
            std::hint::black_box(deco.run(&[b[0].clone(), b[1].clone()]).unwrap());
        });
        // scale hardware time to the serving activation size
        let scaled = hw.as_secs_f64() * (s * d) as f64 / (hs * hd) as f64;
        let transfer = channel.transfer_time(s * d * 4 / ratio as usize);
        let total = scaled + transfer.as_secs_f64() + server_time.as_secs_f64();
        let mut row = Json::obj();
        row.set("codec_s", Json::Num(scaled));
        row.set("share", Json::Num(scaled / total));
        out.set("fc_hw", row);
        println!("{:10} {:>12.3?} (scaled) share {:.2}%", "fc_hw",
                 Duration::from_secs_f64(scaled), 100.0 * scaled / total);
    }

    std::fs::create_dir_all("results")?;
    std::fs::write("results/fig6.json", out.to_string_pretty())?;
    println!("\nwrote results/fig6.json");
    Ok(())
}
