//! Table IV — total activation compression + decompression time per
//! method across the paper's model hidden sizes (1536 / 2048 / 3072),
//! software (native rust codecs) and hardware-offload proxy (the
//! XLA-compiled truncated-DFT artifact).  Emits the same rows the
//! paper reports plus results/table4.json.

use fourier_compress::codec::{self, Codec, CodecEngine, Payload};
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::tensor::{MatView, Tensor};
use fourier_compress::util::bench::{bench, once};
use fourier_compress::util::json::Json;
use fourier_compress::util::rng::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    println!("== Table IV: codec compress+decompress time ==");
    let store = ArtifactStore::open("artifacts").ok();
    let sizes = [(256usize, 1536usize), (256, 2048), (256, 3072)];
    let ratio = 8.0;
    let mut out = Json::obj();

    for (s, d) in sizes {
        println!("\n-- activation {s}x{d} (ratio {ratio}) --");
        let mut rng = Rng::new((s + d) as u64);
        let mut a = vec![0.0f32; s * d];
        rng.fill_normal_f32(&mut a, 1.0);
        let mut row = Json::obj();

        // fast codecs: repeated timing
        for name in ["fc", "topk", "int8"] {
            let c = codec::by_name(name)?;
            let r = bench(&format!("{name}(software) {s}x{d}"), 12,
                          Duration::from_secs(8), || {
                let p = c.compress(&a, s, d, ratio).unwrap();
                std::hint::black_box(c.decompress(&p).unwrap());
            });
            row.set(name, Json::Num(r.median.as_secs_f64()));
        }
        // fc through a warm per-session engine (the serving decode
        // loop's cost model: cached plans/index sets, zero alloc)
        {
            let fc = codec::fourier::FourierCodec::default();
            let view = MatView::new(&a, s, d);
            let mut eng = CodecEngine::new();
            let mut p = Payload::empty();
            let mut rec: Vec<f32> = Vec::new();
            fc.compress_into(&mut eng, view, ratio, &mut p)?; // warm-up
            fc.decompress_into(&mut eng, &p, &mut rec)?;
            let r = bench(&format!("fc(engine)   {s}x{d}"), 12,
                          Duration::from_secs(8), || {
                fc.compress_into(&mut eng, view, ratio, &mut p).unwrap();
                fc.decompress_into(&mut eng, &p, &mut rec).unwrap();
                std::hint::black_box(&rec);
            });
            row.set("fc_engine", Json::Num(r.median.as_secs_f64()));
        }
        // slow factorizations: single run (matches the paper's regime
        // where these are orders of magnitude slower)
        for name in ["qr", "fwsvd", "asvd", "svdllm"] {
            let c = codec::by_name(name)?;
            let dt = once(&format!("{name}(software) {s}x{d}"), || {
                let p = c.compress(&a, s, d, ratio).unwrap();
                std::hint::black_box(c.decompress(&p).unwrap());
            });
            row.set(name, Json::Num(dt.as_secs_f64()));
        }

        // hardware-offload proxy: XLA-compiled matmul-DFT artifacts
        if let Some(store) = &store {
            if let Some(entries) = store.manifest.path("codec_hw.entries")
                .and_then(|v| v.as_arr()) {
                if let Some(e) = entries.iter().find(|e| {
                    e.usize_or("seq", 0) == s && e.usize_or("hidden", 0) == d
                }) {
                    let comp = store.get(e.get("compress_mm").unwrap()
                        .as_str().unwrap())?;
                    let deco = store.get(e.get("decompress_mm").unwrap()
                        .as_str().unwrap())?;
                    let at = Tensor::f32(vec![s, d], a.clone());
                    let r = bench(&format!("fc(hardware) {s}x{d}"), 12,
                                  Duration::from_secs(8), || {
                        let block = comp.run(std::slice::from_ref(&at)).unwrap();
                        std::hint::black_box(
                            deco.run(&[block[0].clone(), block[1].clone()])
                                .unwrap());
                    });
                    row.set("fc_hw", Json::Num(r.median.as_secs_f64()));
                }
            }
        }
        out.set(&format!("{s}x{d}"), row);
    }

    std::fs::create_dir_all("results")?;
    std::fs::write("results/table4.json", out.to_string_pretty())?;
    println!("\nwrote results/table4.json");
    Ok(())
}
