//! Entropy-wire bench: the lossless `codec::wire` coding layer,
//! measured two ways and hard-asserted so the CI smoke step fails
//! loudly on a regression.
//!
//! 1. End to end through the *real* serving core (forged artifacts,
//!    in-proc transport): entropy off vs on in both the recompute and
//!    the delta-stream regime, at bit-identical output tokens, with
//!    the try-and-compare never-worse contract and the exact byte
//!    reconciliation (entropy bytes + bytes saved == raw bytes).
//! 2. A 128-step delta stream over the band-limited activation family
//!    (`testkit::band_limited_act`, the family the forged models
//!    produce at the layer-1 boundary) at the serving-like 64x128
//!    geometry of stream_bench — every coded frame decoded back and
//!    checked bit-exact, and the entropy layer hard-asserted to shave
//!    >= 1.25x additional wire bytes off the already delta-compressed
//!    stream.
//!
//! Plus ns/KiB encode/decode rows for each plane kind (f32 keyframe,
//! sparse updates, int8) in the written JSON.  Writes
//! BENCH_entropy.json.
//!
//!     cargo bench --bench entropy_bench

use fourier_compress::codec::fourier::FourierCodec;
use fourier_compress::codec::quant::{i8_plane, Int8Codec};
use fourier_compress::codec::stream::{BlockGeom, StreamConfig, StreamEncoder,
                                      StreamStep, UPDATE_WIRE_BYTES};
use fourier_compress::codec::{wire, Codec, CodecEngine};
use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::protocol::Frame;
use fourier_compress::coordinator::{start_service, DeviceClient};
use fourier_compress::model::tokenizer;
use fourier_compress::testkit::{band_limited_act, forged_store};
use fourier_compress::util::bench::bench;
use fourier_compress::util::json::Json;
use fourier_compress::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

const STEPS: usize = 22;
const PROMPT: &str = "Q rok ? A"; // 10 tokens; 22 steps stay <= bucket 32
const BAND_STEPS: usize = 128;

fn gen_steps(c: &mut DeviceClient, steps: usize) -> (Vec<i32>, u64) {
    let mut ctx = tokenizer::encode_prompt(PROMPT);
    let b0 = c.stats.bytes_sent;
    let mut toks = Vec::with_capacity(steps);
    for _ in 0..steps {
        let (t, _) = c.step(&ctx).expect("step");
        ctx.push(t);
        toks.push(t);
    }
    (toks, c.stats.bytes_sent - b0)
}

/// One ns/KiB coding row: median encode and decode time over a plane,
/// normalised per KiB of *raw* payload, plus the achieved byte split.
fn coding_row(plane: &str, raw_bytes: usize, coded: &[u8],
              enc_ns: f64, dec_ns: f64) -> Json {
    let kib = raw_bytes as f64 / 1024.0;
    let mut row = Json::obj();
    row.set("plane", Json::Str(plane.into()));
    row.set("raw_bytes", Json::Num(raw_bytes as f64));
    row.set("coded_bytes", Json::Num(coded.len() as f64));
    row.set("ratio_x", Json::Num(raw_bytes as f64 / coded.len() as f64));
    row.set("encode_ns_per_kib", Json::Num(enc_ns / kib));
    row.set("decode_ns_per_kib", Json::Num(dec_ns / kib));
    println!("{plane}: {raw_bytes} B -> {} B ({:.2}x), encode \
              {:.0} ns/KiB, decode {:.0} ns/KiB",
             coded.len(), raw_bytes as f64 / coded.len() as f64,
             enc_ns / kib, dec_ns / kib);
    row
}

fn main() {
    let mut out = Json::obj();

    // ------------------------------------------------------------------
    // leg 1: the real serving core, entropy off vs on, both regimes
    // ------------------------------------------------------------------
    let store = Arc::new(forged_store("entropy_bench").expect("forge"));
    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".to_string(),
        format!("artifacts={}", store.root.display()),
    ]).unwrap();
    let handle = start_service(&cfg, store.clone()).expect("service");

    // recompute regime, raw frames
    let mut rc = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 1).unwrap();
    let (base_tokens, rc_raw) = gen_steps(&mut rc, STEPS);
    rc.bye().unwrap();

    // recompute regime, entropy coded
    let mut re = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 2).unwrap();
    assert!(re.enable_entropy(), "entropy capability must negotiate");
    let (re_tokens, rc_ent) = gen_steps(&mut re, STEPS);
    assert_eq!(re_tokens, base_tokens,
               "entropy coding moved the recompute output tokens");
    assert!(rc_ent <= rc_raw,
            "entropy recompute {rc_ent} B vs raw {rc_raw} B — the \
             try-and-compare contract never ships a larger frame");
    assert_eq!(re.stats.entropy_frames + re.stats.entropy_fallbacks,
               STEPS as u64);
    let re_saved = re.stats.pre_coding_bytes - re.stats.post_coding_bytes;
    assert_eq!(rc_ent + re_saved, rc_raw,
               "recompute byte accounting does not reconcile");
    let (re_frames, re_falls) =
        (re.stats.entropy_frames, re.stats.entropy_fallbacks);
    re.bye().unwrap();

    // delta-stream regime, raw frames (lossless stream: drift 0)
    let sc = StreamConfig { keyframe_interval: 64, drift_threshold: 0.0 };
    let mut sr = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 3).unwrap();
    assert!(sr.enable_stream(sc), "stream capability must negotiate");
    let (sr_tokens, st_raw) = gen_steps(&mut sr, STEPS);
    assert_eq!(sr_tokens, base_tokens, "raw stream diverged from recompute");
    sr.bye().unwrap();

    // delta-stream regime, entropy coded
    let mut se = DeviceClient::connect_over(
        Box::new(handle.connect_inproc()), &store, 4).unwrap();
    assert!(se.enable_stream(sc));
    assert!(se.enable_entropy());
    let (se_tokens, st_ent) = gen_steps(&mut se, STEPS);
    assert_eq!(se_tokens, base_tokens,
               "entropy coding moved the stream output tokens");
    assert_eq!(se.stats.resyncs, 0);
    assert!(st_ent <= st_raw,
            "entropy stream {st_ent} B vs raw stream {st_raw} B");
    let se_saved = se.stats.pre_coding_bytes - se.stats.post_coding_bytes;
    assert_eq!(st_ent + se_saved, st_raw,
               "stream byte accounting does not reconcile");
    let (se_frames, se_falls) =
        (se.stats.entropy_frames, se.stats.entropy_fallbacks);
    se.bye().unwrap();
    handle.shutdown();

    let rc_x = rc_raw as f64 / rc_ent.max(1) as f64;
    let st_x = st_raw as f64 / st_ent.max(1) as f64;
    println!("serving recompute: raw {rc_raw} B, entropy {rc_ent} B \
              ({rc_x:.2}x, {re_frames} coded / {re_falls} fallback)");
    println!("serving stream:    raw {st_raw} B, entropy {st_ent} B \
              ({st_x:.2}x, {se_frames} coded / {se_falls} fallback)");

    out.set("steps", Json::Num(STEPS as f64));
    out.set("recompute_raw_bytes", Json::Num(rc_raw as f64));
    out.set("recompute_entropy_bytes", Json::Num(rc_ent as f64));
    out.set("recompute_savings_x", Json::Num(rc_x));
    out.set("recompute_entropy_frames", Json::Num(re_frames as f64));
    out.set("recompute_entropy_fallbacks", Json::Num(re_falls as f64));
    out.set("stream_raw_bytes", Json::Num(st_raw as f64));
    out.set("stream_entropy_bytes", Json::Num(st_ent as f64));
    out.set("stream_savings_x", Json::Num(st_x));
    out.set("stream_entropy_frames", Json::Num(se_frames as f64));
    out.set("stream_entropy_fallbacks", Json::Num(se_falls as f64));
    out.set("token_parity", Json::Bool(true));

    // ------------------------------------------------------------------
    // leg 2: the band-limited activation family at stream_bench's
    // serving-like geometry — the hard >= 1.25x gate
    // ------------------------------------------------------------------
    let geom = BlockGeom { rows: 64, cols: 128, ks: 33, kd: 15 };
    let n = geom.ks * geom.kd;
    let bins = 2;
    let act = band_limited_act(geom.rows, geom.cols, bins, 0x1FC9);
    let fc = FourierCodec::default();
    let p = fc.compress_block(&act, geom.rows, geom.cols, geom.ks, geom.kd)
        .expect("fc compress");
    // fc payload body: u16 ks | u16 kd | f32 packed[ks*kd], all LE
    assert_eq!(p.body.len(), 4 + n * 4, "unexpected fc payload layout");
    let (ks, kd) = (u16::from_le_bytes([p.body[0], p.body[1]]) as usize,
                    u16::from_le_bytes([p.body[2], p.body[3]]) as usize);
    assert_eq!((ks, kd), (geom.ks, geom.kd));
    let mut truth: Vec<f32> = p.body[4..].chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    // the in-band slots: the hidden axis is band-limited, so only the
    // kept columns inside the band carry signal — the rest of the
    // packed plane is FFT round-off.  Decode-step evolution moves the
    // signal, never the round-off floor.
    let sig: Vec<usize> = truth.iter().enumerate()
        .filter(|(_, v)| v.abs() > 1e-2)
        .map(|(i, _)| i)
        .collect();
    assert!(sig.len() >= 16 && sig.len() <= n / 2,
            "band-limited plane has {} signal slots of {n} — the \
             family or the geometry changed", sig.len());

    let scfg = StreamConfig { keyframe_interval: 16, drift_threshold: 0.0 };
    let mut enc = StreamEncoder::new(scfg);
    let mut eng = CodecEngine::new();
    let mut step = StreamStep::default();
    let mut rng = Rng::new(0x1FC9);
    let mut coded = Vec::new();
    let mut decoded_f = Vec::new();
    let mut decoded_u = Vec::new();
    let (mut raw_bytes, mut ent_bytes) = (0u64, 0u64);
    let (mut keys, mut deltas, mut coded_frames, mut fallbacks) =
        (0u64, 0u64, 0u64, 0u64);
    for t in 0..BAND_STEPS as u64 {
        if t > 0 {
            // decode-step evolution: in-band spectral coefficients move
            for _ in 0..40 {
                let i = sig[rng.below(sig.len())];
                truth[i] += rng.normal() as f32;
            }
        }
        enc.encode_into(&mut eng, geom, &truth, &mut step).unwrap();
        if step.keyframe { keys += 1 } else { deltas += 1 }

        // entropy off: the PR-5 stream frame as-is
        let raw_frame = Frame::Delta {
            session: 1, request: t + 1, seq: step.seq, keyframe: step.keyframe,
            bucket: geom.rows as u16, true_len: geom.rows as u16,
            ks: geom.ks as u16, kd: geom.kd as u16, point: 0,
            packed: step.packed.clone(), updates: step.updates.clone(),
            coded: vec![],
        };
        raw_bytes += raw_frame.encode().len() as u64;

        // entropy on: the client's try-and-compare, then decode the
        // coded body back and check it bit-exact (what the server sees)
        coded.clear();
        if step.keyframe {
            wire::encode_f32_plane(&step.packed, &mut coded);
        } else {
            wire::encode_updates(&step.updates, &mut coded);
        }
        if coded.len() < step.body_bytes() {
            coded_frames += 1;
            if step.keyframe {
                wire::decode_f32_plane(&coded, &mut decoded_f).unwrap();
                assert!(decoded_f.iter().map(|v| v.to_bits())
                            .eq(step.packed.iter().map(|v| v.to_bits())),
                        "coded keyframe is not bit-exact");
            } else {
                wire::decode_updates(&coded, &mut decoded_u).unwrap();
                let mut want = step.updates.clone();
                want.sort_unstable_by_key(|&(i, _)| i);
                assert!(decoded_u.iter().map(|&(i, v)| (i, v.to_bits()))
                            .eq(want.iter().map(|&(i, v)| (i, v.to_bits()))),
                        "coded delta is not bit-exact");
            }
            let ent_frame = Frame::Delta {
                session: 1, request: t + 1, seq: step.seq,
                keyframe: step.keyframe, bucket: geom.rows as u16,
                true_len: geom.rows as u16, ks: geom.ks as u16,
                kd: geom.kd as u16, point: 0, packed: vec![],
                updates: vec![], coded: std::mem::take(&mut coded),
            };
            ent_bytes += ent_frame.encode().len() as u64;
        } else {
            fallbacks += 1;
            ent_bytes += raw_frame.encode().len() as u64;
        }
    }
    let band_x = raw_bytes as f64 / ent_bytes as f64;
    println!("band-limited stream, {BAND_STEPS} steps @ {}x{} block {}x{} \
              (bins {bins}): raw {raw_bytes} B, entropy {ent_bytes} B \
              ({band_x:.2}x, {keys} keys / {deltas} deltas, {coded_frames} \
              coded / {fallbacks} fallback)",
             geom.rows, geom.cols, geom.ks, geom.kd);
    assert!(band_x >= 1.25,
            "entropy coding saved only {band_x:.2}x additional wire bytes \
             on the band-limited stream (need >= 1.25x)");

    out.set("band_steps", Json::Num(BAND_STEPS as f64));
    out.set("band_geometry", Json::Str(format!(
        "{}x{} block {}x{} bins {bins}", geom.rows, geom.cols, geom.ks,
        geom.kd)));
    out.set("band_raw_bytes", Json::Num(raw_bytes as f64));
    out.set("band_entropy_bytes", Json::Num(ent_bytes as f64));
    out.set("band_savings_x", Json::Num(band_x));
    out.set("band_key_frames", Json::Num(keys as f64));
    out.set("band_delta_frames", Json::Num(deltas as f64));
    out.set("band_coded_frames", Json::Num(coded_frames as f64));
    out.set("band_fallbacks", Json::Num(fallbacks as f64));

    // ------------------------------------------------------------------
    // leg 3: ns/KiB encode + decode per plane kind
    // ------------------------------------------------------------------
    let mut rows = Vec::new();
    let budget = Duration::from_secs(1);

    // f32 keyframe plane (the final truth block of the band scenario)
    let mut buf = Vec::new();
    let enc_t = bench("wire encode f32 plane", 400, budget, || {
        buf.clear();
        wire::encode_f32_plane(&truth, &mut buf);
        std::hint::black_box(&buf);
    });
    let dec_t = bench("wire decode f32 plane", 400, budget, || {
        wire::decode_f32_plane(&buf, &mut decoded_f).unwrap();
        std::hint::black_box(&decoded_f);
    });
    rows.push(coding_row("f32_keyframe", truth.len() * 4, &buf,
                         enc_t.median.as_nanos() as f64,
                         dec_t.median.as_nanos() as f64));

    // sparse update list (64 in-band updates, serving-delta shaped)
    let updates: Vec<(u32, f32)> = sig.iter().step_by(2).take(64)
        .map(|&i| (i as u32, truth[i]))
        .collect();
    let raw_u = 4 + updates.len() * UPDATE_WIRE_BYTES;
    let enc_t = bench("wire encode updates", 400, budget, || {
        buf.clear();
        wire::encode_updates(&updates, &mut buf);
        std::hint::black_box(&buf);
    });
    let dec_t = bench("wire decode updates", 400, budget, || {
        wire::decode_updates(&buf, &mut decoded_u).unwrap();
        std::hint::black_box(&decoded_u);
    });
    rows.push(coding_row("sparse_updates", raw_u, &buf,
                         enc_t.median.as_nanos() as f64,
                         dec_t.median.as_nanos() as f64));

    // int8 plane (the quantized codec's wire body)
    let qp = Int8Codec::default()
        .compress(&act, geom.rows, geom.cols, 4.0)
        .expect("int8 compress");
    let q = i8_plane(&qp).expect("i8 plane");
    let mut qdec = Vec::new();
    let enc_t = bench("wire encode i8 plane", 400, budget, || {
        buf.clear();
        wire::encode_i8_plane(&q, &mut buf);
        std::hint::black_box(&buf);
    });
    let dec_t = bench("wire decode i8 plane", 400, budget, || {
        wire::decode_i8_plane(&buf, &mut qdec).unwrap();
        std::hint::black_box(&qdec);
    });
    rows.push(coding_row("i8_plane", q.len(), &buf,
                         enc_t.median.as_nanos() as f64,
                         dec_t.median.as_nanos() as f64));

    out.set("coding", Json::Arr(rows));
    std::fs::write("BENCH_entropy.json", out.to_string_pretty())
        .expect("write BENCH_entropy.json");
    println!("wrote BENCH_entropy.json");
}
